//! The scoped worker pool behind [`super::Ctx::run`].
//!
//! Std-only (no rayon/crossbeam in the offline vendor set): a shared
//! FIFO injector queue guarded by one mutex, long-lived worker threads,
//! and a fork-join `run(n, f)` scope in which the **caller participates**
//! — it pushes its `n` tasks, then pops and executes jobs itself until its
//! scope completes, so a 1-thread pool degenerates to plain inline
//! execution and progress never depends on worker scheduling.
//!
//! ## Why this is sound
//!
//! `run` type-erases the caller's closure to a raw fat pointer and blocks
//! until every one of its tasks has finished executing, so the pointer
//! (and everything the closure borrows) outlives all uses.  Panics inside
//! tasks are caught on the executing thread, recorded on the scope, and
//! re-raised on the calling thread after the join — the scope never
//! returns (or unwinds) while a worker still holds its pointers.
//!
//! ## Why this is deterministic
//!
//! The pool itself guarantees only *which* task indices run (each exactly
//! once) — never an ordering.  Determinism is the contract of the callers
//! (see the [`super`] module docs): tasks write disjoint slots and any
//! combination step is ordered, so the observable result is independent
//! of scheduling — bitwise, not approximately.
//!
//! Nested `run` calls are allowed: a task that opens its own scope drains
//! the shared queue while waiting, so the nesting bottoms out at leaf
//! tasks and cannot deadlock.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::MAX_THREADS;

/// Tasks-per-scope histogram buckets: [1, 2–3, 4–7, 8–15, ≥16].
pub const HIST_BUCKETS: usize = 5;

fn hist_bucket(n: usize) -> usize {
    match n {
        0..=1 => 0,
        2..=3 => 1,
        4..=7 => 2,
        8..=15 => 3,
        _ => 4,
    }
}

/// One scope's shared state, living on the calling thread's stack for the
/// duration of `run` (jobs hold raw pointers to it — see module docs).
/// The closure reference is lifetime-erased to `'static` when the scope is
/// built (`run` blocks until every task has finished, so the erasure never
/// outlives the borrow).
struct ScopeState {
    f: &'static (dyn Fn(usize) + Sync),
    remaining: AtomicUsize,
    panicked: AtomicBool,
}

struct Job {
    scope: *const ScopeState,
    index: usize,
}

// SAFETY: the pointed-at ScopeState (and the closure it points to) is kept
// alive by the blocked `run` caller until `remaining` hits zero, and all
// fields reached through the pointers are Sync.
unsafe impl Send for Job {}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    /// Workers block here for jobs.
    work_cv: Condvar,
    /// Scope callers block here for their stolen tasks to finish.
    done_cv: Condvar,
    tasks_run: AtomicU64,
    scopes_run: AtomicU64,
    max_queue_depth: AtomicUsize,
    scope_size_hist: [AtomicU64; HIST_BUCKETS],
}

fn exec_job(shared: &Shared, job: Job) {
    // SAFETY: see `Job`'s Send justification — the scope outlives this call.
    let scope = unsafe { &*job.scope };
    let f = scope.f;
    if catch_unwind(AssertUnwindSafe(|| f(job.index))).is_err() {
        scope.panicked.store(true, Ordering::Release);
    }
    shared.tasks_run.fetch_add(1, Ordering::Relaxed);
    if scope.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        // last task of the scope: take the lock before notifying so the
        // caller can't check-then-sleep between our decrement and notify
        let _guard = shared.state.lock().unwrap();
        shared.done_cv.notify_all();
    }
}

/// Point-in-time pool gauges for the serving metrics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolStats {
    /// Configured thread count (caller thread included).
    pub threads: usize,
    /// Fork-join scopes opened.
    pub scopes_run: u64,
    /// Tasks executed (inline fast-path included).
    pub tasks_run: u64,
    /// High-water injector queue depth.
    pub max_queue_depth: usize,
    /// Tasks-per-scope histogram: [1, 2–3, 4–7, 8–15, ≥16].
    pub scope_size_hist: [u64; HIST_BUCKETS],
}

/// A fixed-size scoped worker pool.  `threads` counts the participating
/// caller, so `Pool::new(1)` spawns no OS threads at all.
pub struct Pool {
    shared: Arc<Shared>,
    threads: usize,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.clamp(1, MAX_THREADS);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            tasks_run: AtomicU64::new(0),
            scopes_run: AtomicU64::new(0),
            max_queue_depth: AtomicUsize::new(0),
            scope_size_hist: Default::default(),
        });
        let workers = (1..threads)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("memdiff-exec-{w}"))
                    .spawn(move || Self::worker_loop(&shared))
                    .expect("spawn exec worker")
            })
            .collect();
        Pool { shared, threads, workers }
    }

    /// Configured thread count (caller included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            threads: self.threads,
            scopes_run: self.shared.scopes_run.load(Ordering::Relaxed),
            tasks_run: self.shared.tasks_run.load(Ordering::Relaxed),
            max_queue_depth: self.shared.max_queue_depth.load(Ordering::Relaxed),
            scope_size_hist: std::array::from_fn(|i| {
                self.shared.scope_size_hist[i].load(Ordering::Relaxed)
            }),
        }
    }

    fn worker_loop(shared: &Shared) {
        loop {
            let job = {
                let mut st = shared.state.lock().unwrap();
                loop {
                    if let Some(j) = st.jobs.pop_front() {
                        break Some(j);
                    }
                    if st.shutdown {
                        break None;
                    }
                    st = shared.work_cv.wait(st).unwrap();
                }
            };
            match job {
                Some(j) => exec_job(shared, j),
                None => return,
            }
        }
    }

    /// Run tasks `0..n` — each exactly once — and block until all have
    /// completed.  The caller executes tasks too (it is thread 0 of the
    /// pool); with no workers, or a single task, this is a plain inline
    /// loop.  Panics in any task re-raise here after the scope joins.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        self.shared.scopes_run.fetch_add(1, Ordering::Relaxed);
        self.shared.scope_size_hist[hist_bucket(n)].fetch_add(1, Ordering::Relaxed);
        if self.workers.is_empty() || n == 1 {
            for i in 0..n {
                f(i);
            }
            self.shared.tasks_run.fetch_add(n as u64, Ordering::Relaxed);
            return;
        }

        // SAFETY: erase the closure's lifetime so the queue (which is
        // 'static) can reference it.  Sound because this function does not
        // return until `remaining` hits zero — no task can touch `f` after
        // the borrow ends.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync),
                                  &'static (dyn Fn(usize) + Sync)>(f)
        };
        let scope = ScopeState {
            f: f_static,
            remaining: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            for index in 0..n {
                st.jobs.push_back(Job { scope: &scope, index });
            }
            let depth = st.jobs.len();
            self.shared.max_queue_depth.fetch_max(depth, Ordering::Relaxed);
            self.shared.work_cv.notify_all();
        }
        // participate until this scope has no queued work left (FIFO keeps
        // the wait for our own jobs bounded even under concurrent scopes)
        while scope.remaining.load(Ordering::Acquire) > 0 {
            let job = self.shared.state.lock().unwrap().jobs.pop_front();
            match job {
                Some(j) => exec_job(&self.shared, j),
                None => break,
            }
        }
        // tasks stolen by workers may still be in flight
        {
            let mut st = self.shared.state.lock().unwrap();
            while scope.remaining.load(Ordering::Acquire) > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
        }
        if scope.panicked.load(Ordering::Acquire) {
            panic!("exec::Pool task panicked");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_index_runs_exactly_once() {
        let pool = Pool::new(4);
        for n in [1usize, 2, 3, 7, 64, 257] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} of n={n}");
            }
        }
    }

    #[test]
    fn single_thread_pool_is_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        assert!(pool.workers.is_empty());
        let hits: Vec<AtomicU64> = (0..9).map(|_| AtomicU64::new(0)).collect();
        pool.run(9, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scope_blocks_until_all_tasks_finish() {
        // tasks record completion; after run() returns, all must be done
        let pool = Pool::new(4);
        let done = AtomicUsize::new(0);
        pool.run(32, &|_| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn panic_in_task_propagates_to_caller() {
        let pool = Pool::new(3);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // pool is still usable afterwards
        let ok = AtomicUsize::new(0);
        pool.run(4, &|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn nested_scopes_complete() {
        let pool = Pool::new(4);
        let count = AtomicUsize::new(0);
        pool.run(4, &|_| {
            pool.run(4, &|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(count.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn stats_accumulate() {
        let pool = Pool::new(2);
        pool.run(3, &|_| {});
        pool.run(1, &|_| {});
        let s = pool.stats();
        assert_eq!(s.threads, 2);
        assert_eq!(s.scopes_run, 2);
        assert_eq!(s.tasks_run, 4);
        assert_eq!(s.scope_size_hist[hist_bucket(3)], 1);
        assert_eq!(s.scope_size_hist[hist_bucket(1)], 1);
    }

    #[test]
    fn thread_count_clamped() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::new(MAX_THREADS + 100).threads(), MAX_THREADS);
    }
}
