//! [`Shards`]: split one mutable buffer into per-task disjoint `&mut`
//! ranges for the pool's fixed task→output-slot contract.
//!
//! `std`'s `chunks_mut` cannot hand chunk *i* to task *i* through a shared
//! closure, so this wrapper does: ranges are consecutive (hence disjoint)
//! by construction, and a per-shard taken flag guarantees each range is
//! handed out at most once per `Shards` value — together that makes
//! [`Shards::take`] sound without exposing `unsafe` at the call sites.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, Ordering};

/// Disjoint consecutive sub-slices of one backing `&mut [T]`, claimable by
/// index from concurrent pool tasks.
pub struct Shards<'a, T> {
    ptr: *mut T,
    /// (offset, len) per shard; consecutive, so pairwise disjoint.
    spans: Vec<(usize, usize)>,
    taken: Vec<AtomicBool>,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: a Shards value only ever hands out non-overlapping &mut ranges
// (consecutive spans + the taken flags), so sharing it across threads is
// as safe as sending each &mut [T] chunk individually.
unsafe impl<T: Send> Send for Shards<'_, T> {}
unsafe impl<T: Send> Sync for Shards<'_, T> {}

impl<'a, T> Shards<'a, T> {
    /// Split `data` into consecutive shards of the given lengths (their sum
    /// must not exceed `data.len()`; a trailing remainder stays unclaimed).
    pub fn new(data: &'a mut [T], lens: impl IntoIterator<Item = usize>) -> Self {
        let mut spans = Vec::new();
        let mut off = 0usize;
        for len in lens {
            spans.push((off, len));
            off += len;
        }
        assert!(
            off <= data.len(),
            "shard lengths ({off}) exceed the backing slice ({})",
            data.len()
        );
        let taken = spans.iter().map(|_| AtomicBool::new(false)).collect();
        Shards { ptr: data.as_mut_ptr(), spans, taken, _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Claim shard `i`.  Panics if `i` was already claimed — the pool runs
    /// each task index exactly once, so a double claim is a caller bug
    /// (and would otherwise alias the `&mut`).
    pub fn take(&self, i: usize) -> &mut [T] {
        assert!(
            !self.taken[i].swap(true, Ordering::AcqRel),
            "shard {i} claimed twice"
        );
        let (off, len) = self.spans[i];
        // SAFETY: spans are consecutive (disjoint) and the flag above
        // guarantees this range is handed out once for self's lifetime,
        // which is bounded by the backing &'a mut [T].
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(off), len) }
    }
}

/// Turn a task-count hint into a concrete lane-chunk plan `(chunk,
/// n_tasks)` with every task owning at least one lane.  The recompute of
/// `n_tasks` from the rounded-up chunk is load-bearing: without it a
/// trailing task could own zero lanes and slice its inputs out of bounds.
/// Every lane-parallel call site goes through this (and
/// [`lane_chunk_lens`]) so chunk boundaries — and therefore bitwise
/// results — can never drift between layers.
pub fn lane_plan(lanes: usize, tasks_hint: usize) -> (usize, usize) {
    if lanes == 0 {
        return (1, 0);
    }
    if tasks_hint <= 1 {
        return (lanes, 1);
    }
    let chunk = lanes.div_ceil(tasks_hint);
    (chunk, lanes.div_ceil(chunk))
}

/// Per-task lane-chunk lengths: `lanes` rows of `width` elements split into
/// `n_tasks` contiguous chunks of `chunk` rows (last one ragged), matching
/// a [`lane_plan`] result.
pub fn lane_chunk_lens(lanes: usize, width: usize, chunk: usize,
                       n_tasks: usize) -> Vec<usize> {
    (0..n_tasks)
        .map(|i| (lanes - (i * chunk).min(lanes)).min(chunk) * width)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_are_disjoint_and_writable() {
        let mut buf = vec![0u32; 10];
        {
            let sh = Shards::new(&mut buf, [3, 4, 3]);
            assert_eq!(sh.len(), 3);
            let a = sh.take(0);
            let b = sh.take(1);
            let c = sh.take(2);
            assert_eq!((a.len(), b.len(), c.len()), (3, 4, 3));
            a.fill(1);
            b.fill(2);
            c.fill(3);
        }
        assert_eq!(buf, [1, 1, 1, 2, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn trailing_remainder_stays_unclaimed() {
        let mut buf = vec![7u8; 5];
        let sh = Shards::new(&mut buf, [2, 2]);
        sh.take(0).fill(0);
        sh.take(1).fill(0);
        drop(sh);
        assert_eq!(buf[4], 7);
    }

    #[test]
    #[should_panic(expected = "claimed twice")]
    fn double_take_panics() {
        let mut buf = vec![0u8; 4];
        let sh = Shards::new(&mut buf, [2, 2]);
        let _a = sh.take(1);
        let _b = sh.take(1);
    }

    #[test]
    #[should_panic(expected = "exceed the backing slice")]
    fn oversized_lens_panic() {
        let mut buf = vec![0u8; 4];
        let _ = Shards::new(&mut buf, [3, 3]);
    }

    #[test]
    fn lane_chunk_lens_cover_ragged_tails() {
        // 10 lanes of width 3, chunks of 4 → 4+4+2 lanes
        assert_eq!(lane_chunk_lens(10, 3, 4, 3), vec![12, 12, 6]);
        // exact division
        assert_eq!(lane_chunk_lens(8, 2, 4, 2), vec![8, 8]);
        let total: usize = lane_chunk_lens(10, 3, 4, 3).iter().sum();
        assert_eq!(total, 30);
    }

    #[test]
    fn lane_plan_never_yields_zero_lane_tasks() {
        // 5 lanes over a 4-task hint: chunk 2 → only 3 tasks (not 4, whose
        // last task would own zero lanes)
        assert_eq!(lane_plan(5, 4), (2, 3));
        assert_eq!(lane_plan(8, 4), (2, 4));
        assert_eq!(lane_plan(3, 8), (1, 3));
        assert_eq!(lane_plan(7, 1), (7, 1));
        assert_eq!(lane_plan(0, 4), (1, 0));
        for lanes in 1..40usize {
            for hint in 1..10usize {
                let (chunk, n) = lane_plan(lanes, hint);
                assert!(n * chunk >= lanes && (n - 1) * chunk < lanes,
                        "lanes={lanes} hint={hint} → ({chunk},{n})");
            }
        }
    }
}
