//! Deterministic bank-parallel execution subsystem.
//!
//! The paper's throughput story is that all resistive-memory macros compute
//! *physically in parallel*; this module is the simulator's counterpart — a
//! std-only, scoped worker pool ([`Pool`]) with a **deterministic fork-join
//! contract** that the crossbar and network layers build on:
//!
//! * **Fixed task→output-slot assignment** — a scope runs tasks `0..n`,
//!   each exactly once, and every task writes only to the slot its index
//!   owns ([`Shards`] splits a buffer into per-task disjoint `&mut` ranges,
//!   enforced at runtime).
//! * **Disjoint scratch per task** — no task ever accumulates into memory
//!   another task reads or writes.
//! * **Fixed-order reduction** — whatever combines task outputs (the
//!   tile-column scatter in [`crate::crossbar::bank`], the lane-chunk
//!   layout in the batched lanes) happens in a deterministic order chosen
//!   so the per-output-element float-op sequence is *identical* to the
//!   serial path.  Parallel speed never buys nondeterminism: N-thread
//!   output is bitwise equal to 1-thread output, which is bitwise equal to
//!   the serial oracle (asserted by `rust/tests/parallel_parity.rs`).
//!
//! The two decompositions offered to compute layers:
//!
//! * **Banks** — one task per tile-column of a
//!   [`crate::crossbar::BankedCrossbarLayer`] grid.  A tile-column owns a
//!   disjoint slice of output columns, and folds its tile-rows in
//!   ascending order — the monolithic accumulation order — into private
//!   scratch, which is then *copied* (not float-added) into the shared
//!   output.  Works for the noisy modes too, because PR 2's per-bank RNG
//!   streams make each bank's draw sequence independent of which thread
//!   runs it.
//! * **Lanes** — one task per contiguous chunk of batch lanes.  Each
//!   output element is fully computed by exactly one task with the serial
//!   accumulation order, so no reduction is needed at all.  Restricted to
//!   draw-free paths (Ideal GEMMs, or per-lane RNG streams).
//!
//! [`ParStrategy`] selects the axis (`Serial`/`Banks`/`Lanes`/`Auto`) and
//! [`Ctx`] carries the strategy plus a pool handle through the layers.
//! Thread count resolves from `RUST_PALLAS_THREADS` (or
//! `available_parallelism`); [`shared_sized`] lets the serving
//! [`crate::coordinator::Service`] size engine workers vs. intra-op
//! threads coherently, process-wide.

pub mod pool;
pub mod shards;

pub use pool::{Pool, PoolStats};
pub use shards::{lane_chunk_lens, lane_plan, Shards};

use std::sync::{Arc, OnceLock};

/// Env var selecting the intra-op thread count (the CI matrix pins it to 2
/// so the deterministic-parallel invariant is exercised on every PR).
pub const THREADS_ENV: &str = "RUST_PALLAS_THREADS";

/// Upper bound on pool threads — far above any sane core count, a runaway
/// guard for bad env values.
pub const MAX_THREADS: usize = 64;

/// `Auto` splits a call only above this many flop-ish units of work; below
/// it, fork-join overhead beats the win (a 32×32 MVM is ~1k units).
/// Forced `Banks`/`Lanes` bypass the threshold (tests, benches).
pub const MIN_PAR_WORK: usize = 32_768;

/// Which axis a layer parallelizes over.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParStrategy {
    /// Never fork — the reference path.
    Serial,
    /// One task per macro-bank tile-column (scales wide layers).
    Banks,
    /// One task per contiguous lane chunk (scales large batches).
    Lanes,
    /// Pick per call from the shapes involved (default).
    #[default]
    Auto,
}

impl std::str::FromStr for ParStrategy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "serial" => Ok(ParStrategy::Serial),
            "banks" => Ok(ParStrategy::Banks),
            "lanes" => Ok(ParStrategy::Lanes),
            "auto" => Ok(ParStrategy::Auto),
            other => Err(format!(
                "unknown strategy {other:?} (expected serial|banks|lanes|auto)"
            )),
        }
    }
}

impl std::fmt::Display for ParStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ParStrategy::Serial => "serial",
            ParStrategy::Banks => "banks",
            ParStrategy::Lanes => "lanes",
            ParStrategy::Auto => "auto",
        })
    }
}

/// Thread count from the env var, if set and sane.
pub fn env_threads() -> Option<usize> {
    std::env::var(THREADS_ENV)
        .ok()?
        .trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n >= 1)
        .map(|n| n.min(MAX_THREADS))
}

/// Process default thread count: `RUST_PALLAS_THREADS`, else the machine's
/// available parallelism.  Computed once.
pub fn default_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        env_threads().unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(MAX_THREADS)
        })
    })
}

/// Intra-op pool size that coexists coherently with `workers` engine
/// workers: the env override wins outright.  Otherwise, because the pool
/// is **shared** — every worker participates as thread 0 of its own scopes
/// while the pool's spawned helpers are a common resource — the right size
/// is `cores − (workers − 1)`: when all workers fork at once, callers plus
/// helpers occupy ≈ all cores, and a lone busy worker can still fan out
/// across the whole machine.
pub fn intra_threads_for_workers(workers: usize) -> usize {
    env_threads().unwrap_or_else(|| {
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        avail.saturating_sub(workers.saturating_sub(1)).clamp(1, MAX_THREADS)
    })
}

static SHARED: OnceLock<Arc<Pool>> = OnceLock::new();

/// The process-shared pool, created on first use at [`default_threads`].
pub fn shared() -> Arc<Pool> {
    SHARED
        .get_or_init(|| Arc::new(Pool::new(default_threads())))
        .clone()
}

/// The process-shared pool, creating it with `threads` if nobody has yet.
/// First sizing wins process-wide (the serving coordinator calls this
/// before any compute so its worker/intra-op split sticks).
pub fn shared_sized(threads: usize) -> Arc<Pool> {
    SHARED
        .get_or_init(|| Arc::new(Pool::new(threads)))
        .clone()
}

/// Thread count the shared pool has — or would have — without forcing its
/// creation (planning calls use this on every forward).
pub fn shared_threads_hint() -> usize {
    SHARED
        .get()
        .map(|p| p.threads())
        .unwrap_or_else(default_threads)
}

/// Execution context threaded through the compute layers: a strategy plus
/// a pool handle.  `pool = None` lazily resolves to the process-shared
/// pool, so layer constructors stay allocation- and thread-free until a
/// call actually forks.
#[derive(Clone, Default)]
pub struct Ctx {
    pub strategy: ParStrategy,
    pool: Option<Arc<Pool>>,
}

impl Ctx {
    /// Strategy over the process-shared pool.
    pub fn new(strategy: ParStrategy) -> Self {
        Ctx { strategy, pool: None }
    }

    /// Strategy over an explicit pool (parity tests pin thread counts).
    pub fn with_pool(strategy: ParStrategy, pool: Arc<Pool>) -> Self {
        Ctx { strategy, pool: Some(pool) }
    }

    /// Never forks, never touches a pool.
    pub fn serial() -> Self {
        Ctx { strategy: ParStrategy::Serial, pool: None }
    }

    /// Effective thread count for planning (1 under `Serial`).
    pub fn threads(&self) -> usize {
        if self.strategy == ParStrategy::Serial {
            return 1;
        }
        match &self.pool {
            Some(p) => p.threads(),
            None => shared_threads_hint(),
        }
    }

    /// Run tasks `0..n`, each exactly once, blocking until all complete.
    /// Inline (no pool) when serial or trivially small.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n <= 1 || self.strategy == ParStrategy::Serial {
            for i in 0..n {
                f(i);
            }
            return;
        }
        match &self.pool {
            Some(p) => p.run(n, f),
            None => shared().run(n, f),
        }
    }

    /// How many lane-chunk tasks to split `lanes` rows into for `work`
    /// flop-ish units of total work; 1 = stay serial.  Forced `Lanes`
    /// always splits; `Auto` splits only above [`MIN_PAR_WORK`]; `Banks`
    /// and `Serial` never split along the lane axis.
    pub fn lane_tasks(&self, lanes: usize, work: usize) -> usize {
        if lanes < 2 {
            return 1;
        }
        let t = self.threads();
        if t <= 1 {
            return 1;
        }
        match self.strategy {
            ParStrategy::Serial | ParStrategy::Banks => 1,
            ParStrategy::Lanes => t.min(lanes),
            ParStrategy::Auto => {
                if work >= MIN_PAR_WORK {
                    t.min(lanes)
                } else {
                    1
                }
            }
        }
    }

}

impl std::fmt::Debug for Ctx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ctx")
            .field("strategy", &self.strategy)
            .field("threads", &self.threads())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parses_and_displays() {
        for (s, want) in [
            ("serial", ParStrategy::Serial),
            ("Banks", ParStrategy::Banks),
            (" lanes ", ParStrategy::Lanes),
            ("AUTO", ParStrategy::Auto),
        ] {
            assert_eq!(s.parse::<ParStrategy>().unwrap(), want);
        }
        assert!("rayon".parse::<ParStrategy>().is_err());
        assert_eq!(ParStrategy::Banks.to_string(), "banks");
    }

    #[test]
    fn serial_ctx_never_forks() {
        let ctx = Ctx::serial();
        assert_eq!(ctx.threads(), 1);
        let mut hits = vec![false; 5];
        // inline execution lets the closure borrow mutably via a cell-free
        // trick: run() is inline for Serial, so single-threaded access
        let hits_ptr = std::sync::Mutex::new(&mut hits);
        ctx.run(5, &|i| {
            hits_ptr.lock().unwrap()[i] = true;
        });
        drop(hits_ptr);
        assert!(hits.iter().all(|&h| h));
    }

    #[test]
    fn lane_task_policy() {
        let pool = Arc::new(Pool::new(4));
        let auto = Ctx::with_pool(ParStrategy::Auto, pool.clone());
        // tiny work stays serial under Auto
        assert_eq!(auto.lane_tasks(64, 1_000), 1);
        // big work splits up to min(threads, lanes)
        assert_eq!(auto.lane_tasks(64, MIN_PAR_WORK), 4);
        assert_eq!(auto.lane_tasks(2, MIN_PAR_WORK), 2);
        // forced Lanes ignores the threshold
        let lanes = Ctx::with_pool(ParStrategy::Lanes, pool.clone());
        assert_eq!(lanes.lane_tasks(64, 1), 4);
        // Banks/Serial never split the lane axis
        let banks = Ctx::with_pool(ParStrategy::Banks, pool);
        assert_eq!(banks.lane_tasks(64, usize::MAX), 1);
        assert_eq!(Ctx::serial().lane_tasks(64, usize::MAX), 1);
        // a single lane can never split
        assert_eq!(lanes.lane_tasks(1, usize::MAX), 1);
    }

    #[test]
    fn env_threads_respects_bounds() {
        // don't mutate the process env (tests run concurrently); just check
        // the default path resolves to something sane
        let t = default_threads();
        assert!((1..=MAX_THREADS).contains(&t));
    }
}
