//! Artifact registry: lazily compiles the manifest's HLO programs and
//! exposes typed step/score/decode entry points to the samplers and the
//! coordinator.
//!
//! One executable per (program, batch) pair — PJRT executables are shape-
//! specialized, so the coordinator's batcher pads to the nearest exported
//! batch size (1 or 64 by default).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{anyhow, Context};

use super::client::{Executable, Runtime};
use crate::data::meta::Meta;

/// Output of one fused sampler step.
pub type StepOutput = Vec<f32>;

/// Lazily-compiled artifact registry.
pub struct ArtifactStore {
    runtime: Runtime,
    dir: PathBuf,
    meta: Meta,
    compiled: Mutex<BTreeMap<String, &'static Executable>>,
}

impl ArtifactStore {
    /// Open the default artifacts directory.
    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(Meta::artifacts_dir())
    }

    pub fn open(dir: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let dir = dir.into();
        let meta = Meta::load(dir.join("meta.json"))
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        Ok(ArtifactStore {
            runtime: Runtime::cpu()?,
            dir,
            meta,
            compiled: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn meta(&self) -> &Meta {
        &self.meta
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Largest exported batch ≤ `n`, or the smallest exported batch.
    pub fn pick_batch(&self, n: usize) -> usize {
        let mut best = *self.meta.batches.iter().min().unwrap_or(&1);
        for &b in &self.meta.batches {
            if b <= n && b > best {
                best = b;
            }
        }
        best
    }

    /// Get (compiling on first use) the executable for `name`.
    /// Executables are leaked intentionally: they live for the process and
    /// this sidesteps self-referential storage; the set is tiny (≤8).
    fn get(&self, name: &str) -> anyhow::Result<&'static Executable> {
        let mut map = self.compiled.lock().unwrap();
        if let Some(e) = map.get(name) {
            return Ok(e);
        }
        let spec = self
            .meta
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let exe = self
            .runtime
            .compile_hlo_file(self.dir.join(&spec.file), spec.inputs.clone())?;
        let leaked: &'static Executable = Box::leak(Box::new(exe));
        map.insert(name.to_string(), leaked);
        Ok(leaked)
    }

    /// Pre-compile all artifacts of one batch size (warmup).
    pub fn warmup(&self, batch: usize) -> anyhow::Result<()> {
        for stem in ["step_uncond", "step_cond", "score_uncond", "decoder"] {
            let name = format!("{stem}_b{batch}");
            if self.meta.artifacts.contains_key(&name) {
                self.get(&name)?;
            }
        }
        Ok(())
    }

    /// One fused unconditional sampler step on a batch:
    /// x(b,2), t, dt, mode (1=SDE), noise(b,2) → x'(b,2).
    pub fn step_uncond(&self, batch: usize, x: &[f32], t: f32, dt: f32,
                       mode: f32, noise: &[f32]) -> anyhow::Result<StepOutput> {
        let exe = self.get(&format!("step_uncond_b{batch}"))?;
        exe.run_f32(&[
            (x, &[batch, 2]),
            (&[t], &[]),
            (&[dt], &[]),
            (&[mode], &[]),
            (noise, &[batch, 2]),
        ])
    }

    /// One fused conditional (CFG) sampler step:
    /// + onehot(b,3), lambda.
    #[allow(clippy::too_many_arguments)]
    pub fn step_cond(&self, batch: usize, x: &[f32], t: f32, dt: f32,
                     mode: f32, noise: &[f32], onehot: &[f32],
                     lambda: f32) -> anyhow::Result<StepOutput> {
        let exe = self.get(&format!("step_cond_b{batch}"))?;
        exe.run_f32(&[
            (x, &[batch, 2]),
            (&[t], &[]),
            (&[dt], &[]),
            (&[mode], &[]),
            (noise, &[batch, 2]),
            (onehot, &[batch, 3]),
            (&[lambda], &[]),
        ])
    }

    /// Raw score-field evaluation (Fig. 3d): x(b,2), t → net(b,2).
    pub fn score_uncond(&self, batch: usize, x: &[f32], t: f32)
                        -> anyhow::Result<Vec<f32>> {
        let exe = self.get(&format!("score_uncond_b{batch}"))?;
        exe.run_f32(&[(x, &[batch, 2]), (&[t], &[])])
    }

    /// VAE decode: z(b,2) → images (b,12,12) flattened.
    pub fn decode(&self, batch: usize, z: &[f32]) -> anyhow::Result<Vec<f32>> {
        let exe = self.get(&format!("decoder_b{batch}"))?;
        exe.run_f32(&[(z, &[batch, 2])])
    }

    /// Full digital-baseline sampling via the step artifacts: returns the
    /// final batch states after `n_steps` reverse-time Euler steps.
    /// `onehot` = None → unconditional.  The RNG supplies prior + Wiener
    /// noise.  This is what the paper's GPU baseline executes.
    pub fn sample_digital(&self, batch: usize, n_steps: usize, sde: bool,
                          onehot_lambda: Option<(&[f32], f32)>,
                          rng: &mut crate::util::rng::Rng)
                          -> anyhow::Result<Vec<f32>> {
        let sched = self.meta.sched;
        let mut x = rng.gaussian_vec(batch * 2);
        let mut noise = vec![0.0f32; batch * 2];
        let (dt, ts) = sched.reverse_grid(n_steps);
        let mode = if sde { 1.0 } else { 0.0 };
        for &t in &ts {
            if sde {
                rng.fill_gaussian(&mut noise);
            }
            x = match onehot_lambda {
                None => self.step_uncond(batch, &x, t as f32, dt as f32, mode, &noise)?,
                Some((oh, lam)) => self.step_cond(
                    batch, &x, t as f32, dt as f32, mode, &noise, oh, lam,
                )?,
            };
        }
        Ok(x)
    }
}
