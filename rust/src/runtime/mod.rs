//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Interchange is HLO **text** because the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos.
//!
//! The real client is gated behind `--cfg pjrt_vendored` (the `xla`
//! bindings crate lives only in the offline vendored registry, so a cargo
//! feature could never be additive); the default build uses an
//! API-identical stub whose constructor errors, so artifact-gated callers
//! skip cleanly — see [`client`] and the recipe in `rust/Cargo.toml`.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactStore, StepOutput};
pub use client::Runtime;
