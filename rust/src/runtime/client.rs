//! Thin PJRT client wrapper: compile HLO text, execute with f32 buffers.
//!
//! The real implementation needs the `xla` bindings crate, which exists
//! only in the offline vendored registry.  The in-tree manifest therefore
//! builds a **stub** by default (identical API, every entry point returns
//! an error) so the rest of the stack — simulator, solvers, coordinator —
//! compiles and tests everywhere.  To get the real runtime inside the
//! vendored environment, follow the recipe in `rust/Cargo.toml`
//! (uncomment the `xla` dependency and build with
//! `RUSTFLAGS="--cfg pjrt_vendored"` — a cfg flag, not a cargo feature,
//! so no feature combination can select undeclarable code).  Callers
//! already treat runtime construction as fallible
//! (artifact-gated tests skip when `ArtifactStore::open` fails), so the
//! stub degrades gracefully.

#[cfg(pjrt_vendored)]
mod imp {
    use std::path::Path;

    use anyhow::{anyhow, Context};

    /// The process-wide PJRT CPU client plus compile/execute helpers.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// A compiled executable with its input arity/shapes for validation.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Expected input shapes ([] = scalar).
        pub input_shapes: Vec<Vec<usize>>,
    }

    impl Runtime {
        /// Create the CPU client (one per process is plenty; cheap to share
        /// behind an Arc in the coordinator).
        pub fn cpu() -> anyhow::Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile an HLO-text file into an executable.
        pub fn compile_hlo_file(&self, path: impl AsRef<Path>,
                                input_shapes: Vec<Vec<usize>>) -> anyhow::Result<Executable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            Ok(Executable { exe, input_shapes })
        }
    }

    impl Executable {
        /// Execute with f32 inputs; each input is (data, shape) where shape []
        /// means scalar.  Returns the first (tuple-unwrapped) f32 output.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> anyhow::Result<Vec<f32>> {
            if inputs.len() != self.input_shapes.len() {
                return Err(anyhow!(
                    "arity mismatch: got {}, executable wants {}",
                    inputs.len(),
                    self.input_shapes.len()
                ));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, (data, shape)) in inputs.iter().enumerate() {
                let want = &self.input_shapes[i];
                if *shape != want.as_slice() {
                    return Err(anyhow!("input {i} shape {shape:?} != expected {want:?}"));
                }
                let n: usize = shape.iter().product();
                if data.len() != n.max(1) {
                    return Err(anyhow!("input {i}: {} elems for shape {shape:?}", data.len()));
                }
                let lit = if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?
                };
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple
            let out = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
            out.to_vec::<f32>()
                .map_err(|e| anyhow!("to_vec<f32>: {e:?}"))
                .context("reading executable output")
        }
    }
}

#[cfg(not(pjrt_vendored))]
mod imp {
    use std::path::Path;

    use anyhow::anyhow;

    fn unavailable() -> anyhow::Error {
        anyhow!(
            "PJRT runtime unavailable: memdiff was built without \
             `--cfg pjrt_vendored` (the `xla` bindings crate is only in \
             the offline vendored registry)"
        )
    }

    /// Stub client: construction fails, so artifact-gated callers skip.
    pub struct Runtime {
        _priv: (),
    }

    /// Stub executable (never constructed; kept for API parity).
    pub struct Executable {
        /// Expected input shapes ([] = scalar).
        pub input_shapes: Vec<Vec<usize>>,
    }

    impl Runtime {
        pub fn cpu() -> anyhow::Result<Self> {
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "pjrt-stub".to_string()
        }

        pub fn compile_hlo_file(&self, _path: impl AsRef<Path>,
                                _input_shapes: Vec<Vec<usize>>) -> anyhow::Result<Executable> {
            Err(unavailable())
        }
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> anyhow::Result<Vec<f32>> {
            Err(unavailable())
        }
    }
}

pub use imp::{Executable, Runtime};
