//! Analog circuit blocks and the closed-loop neural differential-equation
//! solver (paper Fig. 2h–j) — the system's core contribution.
//!
//! * [`opamp`]       — op-amp behavioural model (OPAx171): finite gain,
//!   output saturation, single-pole bandwidth; TIA / summing / inverting
//!   configurations.
//! * [`activation`]  — the dual-diode ReLU clamp at the TIA (Fig. 2h).
//! * [`multiplier`]  — AD633 four-quadrant analog multiplier.
//! * [`integrator`]  — op-amp RC integrator with capacitor pre-charge (the
//!   initial condition x_T ~ N(0, I)).
//! * [`solver`]      — the closed loop: analog NN → multipliers applying
//!   the predetermined f(t) and g²(t)/σ(t) waveforms → summing amp → RC
//!   integrator → feedback to the NN input.  Time-continuous: simulated
//!   with fine fixed-step integration far below the signal bandwidth.

pub mod activation;
pub mod integrator;
pub mod multiplier;
pub mod opamp;
pub mod solver;

pub use solver::{AnalogSolver, SolverConfig, SolverMode};
