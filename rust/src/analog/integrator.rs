//! Op-amp RC integrator with capacitor pre-charge (paper Fig. 2j).
//!
//! `v_out(τ) = v0 − (1/RC) ∫ v_in dτ` for the inverting configuration; the
//! solver uses the non-inverted sign convention (a second inverting stage
//! on the PCB).  Pre-charging the capacitor sets the initial condition
//! x_T ~ N(0, I) — that is how a "sample" starts on hardware.
//!
//! Non-idealities modeled: output saturation and capacitor leakage (the
//! integrator slowly forgets, time constant R_leak·C), both of which bound
//! how long a solve can run — one of the reasons the projected system
//! shrinks the solve window to 20 µs.

/// RC integrator state.
#[derive(Debug, Clone)]
pub struct Integrator {
    /// Integration gain 1/(R·C) in 1/s.
    pub inv_rc: f64,
    /// Leakage time constant R_leak·C in seconds (f64::INFINITY = ideal).
    pub leak_tau_s: f64,
    /// Saturation bound (software units).
    pub v_sat: f32,
    /// Current output voltage.
    pub v: f32,
}

impl Integrator {
    /// `rc_s`: integration time constant R·C in seconds.
    pub fn new(rc_s: f64) -> Self {
        Integrator {
            inv_rc: 1.0 / rc_s,
            leak_tau_s: f64::INFINITY,
            v_sat: 120.0,
            v: 0.0,
        }
    }

    pub fn with_leak(mut self, leak_tau_s: f64) -> Self {
        self.leak_tau_s = leak_tau_s;
        self
    }

    /// Pre-charge the capacitor (set the initial condition).
    pub fn precharge(&mut self, v0: f32) {
        self.v = v0.clamp(-self.v_sat, self.v_sat);
    }

    /// Advance by `dt_s` with input `v_in`: v += (v_in/RC)·dt − leak.
    #[inline]
    pub fn step(&mut self, v_in: f32, dt_s: f64) -> f32 {
        let leak = if self.leak_tau_s.is_finite() {
            (self.v as f64) * (dt_s / self.leak_tau_s)
        } else {
            0.0
        };
        self.v = ((self.v as f64) + (v_in as f64) * self.inv_rc * dt_s - leak)
            .clamp(-self.v_sat as f64, self.v_sat as f64) as f32;
        self.v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_constant() {
        let mut i = Integrator::new(1.0); // RC = 1 s
        i.precharge(0.0);
        let dt = 1e-4;
        for _ in 0..10_000 {
            i.step(2.0, dt);
        }
        // ∫ 2 dt over 1 s = 2
        assert!((i.v - 2.0).abs() < 1e-3, "{}", i.v);
    }

    #[test]
    fn precharge_sets_initial_condition() {
        let mut i = Integrator::new(0.5);
        i.precharge(-1.3);
        assert_eq!(i.v, -1.3);
        i.step(0.0, 1e-3);
        assert!((i.v + 1.3).abs() < 1e-6);
    }

    #[test]
    fn rc_scales_rate() {
        let mut fast = Integrator::new(0.1);
        let mut slow = Integrator::new(1.0);
        for _ in 0..1000 {
            fast.step(1.0, 1e-4);
            slow.step(1.0, 1e-4);
        }
        assert!((fast.v / slow.v - 10.0).abs() < 0.01);
    }

    #[test]
    fn leak_decays_state() {
        let mut i = Integrator::new(1.0).with_leak(0.1);
        i.precharge(1.0);
        for _ in 0..10_000 {
            i.step(0.0, 1e-4);
        }
        // one second with tau=0.1 ⇒ e^{-10}
        assert!(i.v < 0.01, "{}", i.v);
    }

    #[test]
    fn saturates() {
        let mut i = Integrator::new(1e-3);
        for _ in 0..100_000 {
            i.step(10.0, 1e-4);
        }
        assert_eq!(i.v, i.v_sat);
    }
}
