//! Diode-clamp ReLU (paper Fig. 2h: two 1N4148 diodes + a TIA).
//!
//! The circuit clamps the inverted TIA output's upper limit to 0 V; after
//! the final inverting stage the transfer is a rectified-linear unit with
//! a soft knee set by the diode's exponential turn-on.  The soft-knee model
//! keeps the solver's vector field Lipschitz (no corner), matching silicon;
//! the knee width is small enough that the digital `max(0, x)` and this
//! function differ by < 2 mV everywhere.

/// Diode thermal-ish knee width in software voltage units (0.1 V == 1).
/// 1N4148 at room temperature: ~2 mV knee after the gain stage ⇒ 0.02 units.
pub const KNEE: f32 = 0.02;

/// Soft ReLU with diode knee: softplus of width [`KNEE`], exact `max(0,x)`
/// outside ±6·KNEE (exp(±6) makes the tails numerically exact in f32).
#[inline(always)]
pub fn relu_diode(x: f32) -> f32 {
    if x > 6.0 * KNEE {
        x
    } else if x < -6.0 * KNEE {
        0.0
    } else {
        KNEE * (x / KNEE).exp().ln_1p()
    }
}

/// Hard ideal ReLU (digital reference).
#[inline(always)]
pub fn relu_ideal(x: f32) -> f32 {
    x.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_outside_knee() {
        assert_eq!(relu_diode(1.0), 1.0);
        assert_eq!(relu_diode(-1.0), 0.0);
        assert_eq!(relu_diode(0.5), 0.5);
    }

    #[test]
    fn close_to_ideal_everywhere() {
        let mut x = -0.5f32;
        while x < 0.5 {
            let d = (relu_diode(x) - relu_ideal(x)).abs();
            assert!(d <= KNEE * 0.7 + 1e-6, "x={x}: diff {d}");
            x += 0.001;
        }
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut prev = relu_diode(-0.3);
        let mut x = -0.3f32;
        while x < 0.3 {
            x += 0.001;
            let y = relu_diode(x);
            assert!(y >= prev - 1e-7, "not monotone at {x}");
            prev = y;
        }
    }

    #[test]
    fn smooth_at_origin() {
        // finite difference slope near 0 must be between 0 and 1
        let h = 1e-3f32;
        let slope = (relu_diode(h) - relu_diode(-h)) / (2.0 * h);
        assert!(slope > 0.2 && slope < 0.8, "slope {slope}");
    }
}
