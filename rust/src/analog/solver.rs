//! The closed-loop, time-continuous analog neural differential-equation
//! solver (paper Fig. 2j) — the system's core contribution.
//!
//! Loop topology, exactly as on the PCB:
//!
//! ```text
//!          ┌──────────────────────────────────────────────┐
//!          │                                              │
//!   x(τ) ──┤ analog score NN (crossbars, Fig. 2h-i)       │
//!          │        net(x, t)                             │
//!          │            │                                 │
//!          │   AD633 ×  g²(t)/σ(t)   (predetermined DAC)  │
//!          │   AD633 ×  f(t)·x       (predetermined DAC)  │
//!          │            │                                 │
//!          │      summing amp  Σ  (+ noise inj. for SDE)  │
//!          │            │                                 │
//!          │      RC integrator  (pre-charged to x_T)     │
//!          └────────────┴──── x(τ) feedback ──────────────┘
//! ```
//!
//! The hardware evolves continuously; we simulate it with a fixed
//! sub-step far below the loop bandwidth (default 2000 sub-steps per
//! solve — the *simulation* grid, not a discretization the hardware
//! performs; halving it changes results below the device-noise floor,
//! which `tests::substep_convergence` verifies).
//!
//! Time mapping (Methods): hardware τ ∈ [0, T_solve] ↔ algorithm
//! t = T·(1 − τ/T_solve), so dt_alg = −(T/T_solve)·dτ and the integrator
//! realizes x₀ = ∫_T^0 F(x,t) dt (paper Eq. 3).
//!
//! The SDE's Wiener term is physical: conductance read noise perturbs every
//! NN evaluation (NoiseModel), and an explicit g(t)·ε noise current can be
//! injected at the summing node (the PCB's noise DAC).  The ODE mode runs
//! the same loop with the noise DAC off.

use super::integrator::Integrator;
use super::multiplier::Multiplier;
use crate::clamp_voltage;
use crate::diffusion::schedule::VpSchedule;
use crate::exec::{self, lane_chunk_lens, lane_plan, Shards};
use crate::nn::{BatchScratch, ScoreNet};
use crate::util::rng::Rng;

/// Probability-flow ODE or reverse SDE (paper Eq. 2 / Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolverMode {
    Ode,
    Sde,
}

/// Solver configuration.
#[derive(Debug, Clone)]
pub struct SolverConfig {
    pub sched: VpSchedule,
    pub mode: SolverMode,
    /// Hardware solve window in seconds (PCB: 1.0; projected system: 20 µs).
    pub t_solve_s: f64,
    /// Simulation sub-steps per solve (fidelity knob, not hardware).
    pub substeps: usize,
    /// CFG guidance strength (None = unconditional).
    pub guidance: Option<f32>,
    /// Integrator RC in seconds; calibrated so the loop gain is unity for
    /// the chosen t_solve (RC = t_solve ⇒ 1/RC·∫v dτ reproduces ∫F dt).
    pub rc_s: f64,
    /// Capacitor leakage time constant (None = ideal capacitor).
    pub leak_tau_s: Option<f64>,
}

impl SolverConfig {
    pub fn new(mode: SolverMode) -> Self {
        let t_solve_s = 1.0;
        SolverConfig {
            sched: VpSchedule::default(),
            mode,
            t_solve_s,
            substeps: 2000,
            guidance: None,
            rc_s: t_solve_s,
            leak_tau_s: None,
        }
    }

    /// Re-time the loop (e.g. the projected 20 µs integrated system); the
    /// RC constant scales with it, as on silicon.
    pub fn with_solve_window(mut self, t_solve_s: f64) -> Self {
        self.rc_s *= t_solve_s / self.t_solve_s;
        self.t_solve_s = t_solve_s;
        self
    }

    pub fn with_guidance(mut self, lambda: f32) -> Self {
        self.guidance = Some(lambda);
        self
    }

    pub fn with_substeps(mut self, n: usize) -> Self {
        self.substeps = n;
        self
    }

    pub fn with_schedule(mut self, sched: VpSchedule) -> Self {
        self.sched = sched;
        self
    }
}

/// The closed-loop solver bound to an analog (or any) score network.
pub struct AnalogSolver<'a> {
    pub net: &'a dyn ScoreNet,
    pub cfg: SolverConfig,
    /// f(t)-path multipliers (one per dimension, matched parts).
    mul_drift: Multiplier,
    /// g²/σ-path multipliers.
    mul_score: Multiplier,
    /// Parallel-execution context for the batched lane's per-sub-step
    /// integrator update (NN GEMMs parallelize inside the net); per-lane
    /// noise-DAC streams keep any chunking bitwise deterministic.
    pub exec: exec::Ctx,
}

impl<'a> AnalogSolver<'a> {
    pub fn new(net: &'a dyn ScoreNet, cfg: SolverConfig) -> Self {
        AnalogSolver {
            net,
            cfg,
            mul_drift: Multiplier::new(1.0),
            mul_score: Multiplier::new(1.0),
            exec: exec::Ctx::default(),
        }
    }

    pub fn with_exec(mut self, exec: exec::Ctx) -> Self {
        self.exec = exec;
        self
    }

    /// Solve one trajectory.  `x0` is the pre-charge (the N(0,I) draw);
    /// the final state overwrites it.  `onehot` may be empty (no classes)
    /// or all-zero (unconditional).  If `trace_every > 0`, intermediate
    /// states are appended to `trace` every that-many sub-steps (for the
    /// Fig. 3e / 4e–f trajectory plots).
    pub fn solve_into(&self, x0: &mut [f32], onehot: &[f32], rng: &mut Rng,
                      trace_every: usize, trace: &mut Vec<(f64, Vec<f32>)>) {
        let dim = x0.len();
        let n = self.cfg.substeps;
        let d_tau = self.cfg.t_solve_s / n as f64;
        // algorithm-time step magnitude per sub-step
        let t_span = self.cfg.sched.t_end - self.cfg.sched.eps_t;
        let dt_alg = t_span / n as f64;

        // integrators, pre-charged with the initial condition
        let mut ints: Vec<Integrator> = (0..dim)
            .map(|i| {
                let mut integ = Integrator::new(self.cfg.rc_s);
                if let Some(tau) = self.cfg.leak_tau_s {
                    integ = integ.with_leak(tau);
                }
                integ.precharge(x0[i]);
                integ
            })
            .collect();

        let mut net_out = vec![0.0f32; dim];
        let mut x = x0.to_vec();

        for k in 0..n {
            let _t_sub = crate::obs::phase(crate::obs::Phase::Substep);
            let tau = k as f64 * d_tau;
            // hardware τ → algorithm t (reverse time)
            let t = self.cfg.sched.t_end - t_span * (tau / self.cfg.t_solve_s);
            let beta = self.cfg.sched.beta(t);
            // predetermined DAC waveforms
            let w_score = self.cfg.sched.g2_over_sigma(t)
                * match self.cfg.mode {
                    SolverMode::Sde => 1.0,
                    SolverMode::Ode => 0.5,
                };
            let w_drift = 0.5 * beta; // −f(x,t) = +β/2·x feeds forward

            // NN inference (device read noise inside)
            match self.cfg.guidance {
                Some(lam) => {
                    self.net
                        .eval_cfg(&x, t as f32, onehot, lam, &mut net_out, rng)
                }
                None => self.net.eval(&x, t as f32, onehot, &mut net_out, rng),
            }

            // per-dimension: multipliers → summing amp → integrator
            for i in 0..dim {
                // Reverse-time update: x(t−dt) = x(t) − dt·F with
                // F = f − g²·score = −β/2·x − (g²/σ)·net  [ε-param.], so
                // dx/dτ = (T/T_solve)·( β/2·x − (g²/σ)·net ).
                let drift_term = self.mul_drift.mul(w_drift as f32, x[i]);
                let score_term = self.mul_score.mul(w_score as f32, net_out[i]);
                let mut v_sum = drift_term - score_term;
                if self.cfg.mode == SolverMode::Sde {
                    // Noise DAC at the summing node.  The integrator turns a
                    // summing-node voltage v into Δx = v·dt_alg per sub-step
                    // (see v_in scaling below), so a Wiener increment
                    // √(β·dt_alg)·ε requires v_noise = √(β/dt_alg)·ε — the
                    // white-noise density the DAC synthesizes.
                    v_sum += ((beta / dt_alg).sqrt() * rng.gaussian()) as f32;
                }
                // loop gain: integrator input scaled so ∫ over τ equals
                // ∫F dt over algorithm time: factor t_span / t_solve · rc
                let v_in = v_sum * (t_span / self.cfg.t_solve_s * self.cfg.rc_s) as f32;
                let xi = ints[i].step(v_in, d_tau);
                x[i] = clamp_voltage(xi);
            }

            if trace_every > 0 && k % trace_every == 0 {
                trace.push((t, x.clone()));
            }
        }
        x0.copy_from_slice(&x);
    }

    /// Batch solve from N(0, I) pre-charges; returns interleaved samples.
    /// Scalar reference lane: one trajectory at a time (a physical PCB has
    /// one loop; see [`Self::solve_batched`] for the multi-lane view).
    pub fn solve_batch(&self, n: usize, onehot: &[f32], rng: &mut Rng) -> Vec<f32> {
        let dim = self.net.dim();
        let mut out = vec![0.0f32; n * dim];
        let mut trace = Vec::new();
        for s in 0..n {
            let x = &mut out[s * dim..(s + 1) * dim];
            {
                let _t = crate::obs::phase(crate::obs::Phase::NoisePass);
                for v in x.iter_mut() {
                    *v = rng.gaussian_f32();
                }
            }
            self.solve_into(x, onehot, rng, 0, &mut trace);
        }
        out
    }

    /// Batched lane: advance all `n` trajectories per sub-step, with every
    /// NN inference a single [`ScoreNet::eval_batch`] GEMM sweep — the
    /// simulator view of a macro bank driving n concurrent integrator
    /// loops, which is how the projected system amortizes the crossbar
    /// model over many generations.  With a banked score net
    /// ([`crate::crossbar::BankedCrossbarLayer`]) each sub-step is one
    /// GEMM per bank, so nets wider than one 32×32 macro run end-to-end
    /// through this lane unchanged.  Priors draw from `rng` lane-by-lane in
    /// the same order as [`Self::solve_batch`]; the SDE noise-DAC
    /// increments come from per-lane streams split off the base rng,
    /// keeping lanes decorrelated and the result deterministic per
    /// (seed, n).  In ODE mode with ideal (noise-free) evaluation this lane
    /// is bitwise identical to the scalar lane; noisy modes agree in
    /// distribution (parity-tested).
    pub fn solve_batched(&self, n: usize, onehot: &[f32], rng: &mut Rng) -> Vec<f32> {
        let dim = self.net.dim();
        let len = n * dim;
        let nsub = self.cfg.substeps;
        let d_tau = self.cfg.t_solve_s / nsub as f64;
        let t_span = self.cfg.sched.t_end - self.cfg.sched.eps_t;
        let dt_alg = t_span / nsub as f64;

        let mut x = vec![0.0f32; len];
        {
            let _t = crate::obs::phase(crate::obs::Phase::NoisePass);
            for v in x.iter_mut() {
                *v = rng.gaussian_f32();
            }
        }
        let mut lane_rngs: Vec<Rng> = (0..n).map(|_| rng.split()).collect();

        // one integrator bank per lane·dimension, pre-charged with priors
        let mut ints: Vec<Integrator> = x
            .iter()
            .map(|&x0| {
                let mut integ = Integrator::new(self.cfg.rc_s);
                if let Some(tau) = self.cfg.leak_tau_s {
                    integ = integ.with_leak(tau);
                }
                integ.precharge(x0);
                integ
            })
            .collect();

        let mut net_out = vec![0.0f32; len];
        let mut scratch = BatchScratch::new();
        let loop_gain = (t_span / self.cfg.t_solve_s * self.cfg.rc_s) as f32;

        // lane-chunk plan for the integrator update, fixed for the whole
        // solve so chunk boundaries (and each lane's noise-DAC stream
        // draws) never move between sub-steps; x and the integrator bank
        // share one lens vector (both are lane×dim)
        let (upd_chunk, upd_tasks) =
            lane_plan(n, self.exec.lane_tasks(n, len));
        let lens_x = lane_chunk_lens(n, dim, upd_chunk, upd_tasks);
        let lens_r = lane_chunk_lens(n, 1, upd_chunk, upd_tasks);

        for k in 0..nsub {
            let _t_sub = crate::obs::phase(crate::obs::Phase::Substep);
            let tau = k as f64 * d_tau;
            let t = self.cfg.sched.t_end - t_span * (tau / self.cfg.t_solve_s);
            let beta = self.cfg.sched.beta(t);
            let w_score = self.cfg.sched.g2_over_sigma(t)
                * match self.cfg.mode {
                    SolverMode::Sde => 1.0,
                    SolverMode::Ode => 0.5,
                };
            let w_drift = 0.5 * beta;

            // one batched NN inference for all lanes
            match self.cfg.guidance {
                Some(lam) => self.net.eval_cfg_batch(&x, t as f32, onehot, lam,
                                                     &mut net_out, &mut scratch,
                                                     rng),
                None => self.net.eval_batch(&x, t as f32, onehot, &mut net_out,
                                            &mut scratch, rng),
            }

            // one update body for both execution shapes: a lane chunk is
            // (states, its integrators, its noise-DAC streams, the chunk's
            // base offset into the shared NN output)
            let no: &[f32] = &net_out;
            let update = |xc: &mut [f32], ic: &mut [Integrator],
                          rngs: &mut [Rng], base: usize| {
                for (bl, lane) in rngs.iter_mut().enumerate() {
                    for j in bl * dim..(bl + 1) * dim {
                        let drift_term =
                            self.mul_drift.mul(w_drift as f32, xc[j]);
                        let score_term =
                            self.mul_score.mul(w_score as f32, no[base + j]);
                        let mut v_sum = drift_term - score_term;
                        if self.cfg.mode == SolverMode::Sde {
                            v_sum +=
                                ((beta / dt_alg).sqrt() * lane.gaussian()) as f32;
                        }
                        let v_in = v_sum * loop_gain;
                        xc[j] = clamp_voltage(ic[j].step(v_in, d_tau));
                    }
                }
            };
            if upd_tasks > 1 {
                // each lane's integrators and noise-DAC stream live whole
                // inside one task, so the chunked update is bitwise equal
                // to the serial call at any thread count
                let sx = Shards::new(&mut x[..], lens_x.iter().copied());
                let si = Shards::new(&mut ints[..], lens_x.iter().copied());
                let sr =
                    Shards::new(&mut lane_rngs[..], lens_r.iter().copied());
                self.exec.run(upd_tasks, &|ti| {
                    update(sx.take(ti), si.take(ti), sr.take(ti),
                           ti * upd_chunk * dim);
                });
            } else {
                update(&mut x[..], &mut ints[..], &mut lane_rngs[..], 0);
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    /// Same analytic Gaussian net as the digital sampler tests.
    struct GaussianNet {
        s0: f64,
        sched: VpSchedule,
    }

    impl ScoreNet for GaussianNet {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            0
        }
        fn eval(&self, x: &[f32], t: f32, _c: &[f32], out: &mut [f32], _r: &mut Rng) {
            let a = self.sched.alpha(t as f64);
            let sg = self.sched.sigma(t as f64);
            let v = a * a * self.s0 * self.s0 + sg * sg;
            for i in 0..x.len() {
                out[i] = (sg * x[i] as f64 / v) as f32;
            }
        }
    }

    fn gaussian_solve(mode: SolverMode, substeps: usize, n: usize) -> Vec<f32> {
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        let cfg = SolverConfig::new(mode).with_substeps(substeps);
        let solver = AnalogSolver::new(&net, cfg);
        let mut rng = Rng::new(7);
        solver.solve_batch(n, &[], &mut rng)
    }

    fn std_x(pts: &[f32]) -> f64 {
        let xs: Vec<f32> = pts.iter().step_by(2).copied().collect();
        stats::std(&xs)
    }

    #[test]
    fn ode_transports_gaussian() {
        let pts = gaussian_solve(SolverMode::Ode, 2000, 1500);
        let s = std_x(&pts);
        assert!((s - 0.5).abs() < 0.05, "std={s}");
    }

    #[test]
    fn sde_transports_gaussian() {
        let pts = gaussian_solve(SolverMode::Sde, 2000, 1500);
        let s = std_x(&pts);
        assert!((s - 0.5).abs() < 0.08, "std={s}");
    }

    #[test]
    fn substep_convergence() {
        // halving the simulation grid must not change the result materially
        let a = std_x(&gaussian_solve(SolverMode::Ode, 1000, 1500));
        let b = std_x(&gaussian_solve(SolverMode::Ode, 2000, 1500));
        assert!((a - b).abs() < 0.02, "{a} vs {b}");
    }

    #[test]
    fn solve_window_invariance() {
        // the *solution* must not depend on the hardware window (1 s PCB vs
        // 20 µs projected): RC scales with it
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        let mut results = Vec::new();
        for window in [1.0, 20e-6] {
            let cfg = SolverConfig::new(SolverMode::Ode)
                .with_substeps(2000)
                .with_solve_window(window);
            let solver = AnalogSolver::new(&net, cfg);
            let mut rng = Rng::new(9);
            results.push(std_x(&solver.solve_batch(800, &[], &mut rng)));
        }
        assert!(
            (results[0] - results[1]).abs() < 1e-6,
            "window must rescale exactly: {results:?}"
        );
    }

    #[test]
    fn capacitor_leak_degrades_gracefully() {
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(1500);
        let leaky = SolverConfig {
            leak_tau_s: Some(10.0), // 10× the solve window
            ..cfg.clone()
        };
        let mut rng = Rng::new(11);
        let ideal = AnalogSolver::new(&net, cfg).solve_batch(800, &[], &mut rng);
        let mut rng = Rng::new(11);
        let leak = AnalogSolver::new(&net, leaky).solve_batch(800, &[], &mut rng);
        let (si, sl) = (std_x(&ideal), std_x(&leak));
        assert!((si - sl).abs() < 0.1, "mild leak must not destroy: {si} vs {sl}");
        assert!((si - sl).abs() > 1e-6, "leak must have *some* effect");
    }

    #[test]
    fn trace_records_trajectory() {
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(1000);
        let solver = AnalogSolver::new(&net, cfg);
        let mut rng = Rng::new(13);
        let mut x = [1.0f32, -1.0];
        let mut trace = Vec::new();
        solver.solve_into(&mut x, &[], &mut rng, 100, &mut trace);
        assert_eq!(trace.len(), 10);
        // algorithm time decreases along the trace (reverse diffusion)
        for w in trace.windows(2) {
            assert!(w[1].0 < w[0].0);
        }
    }

    #[test]
    fn states_respect_protective_clamp() {
        let pts = gaussian_solve(SolverMode::Sde, 800, 400);
        for &v in &pts {
            assert!((-2.0..=4.0).contains(&v));
        }
    }

    fn gaussian_solve_batched(mode: SolverMode, substeps: usize, n: usize) -> Vec<f32> {
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        let cfg = SolverConfig::new(mode).with_substeps(substeps);
        let solver = AnalogSolver::new(&net, cfg);
        let mut rng = Rng::new(7);
        solver.solve_batched(n, &[], &mut rng)
    }

    #[test]
    fn batched_ode_bitwise_matches_scalar() {
        // deterministic loop (ODE, noise-free net): batched lane must
        // reproduce the per-trajectory lane exactly
        let scalar = gaussian_solve(SolverMode::Ode, 300, 7);
        let batched = gaussian_solve_batched(SolverMode::Ode, 300, 7);
        assert_eq!(scalar, batched);
    }

    #[test]
    fn batched_sde_transports_gaussian() {
        let pts = gaussian_solve_batched(SolverMode::Sde, 2000, 1500);
        let s = std_x(&pts);
        assert!((s - 0.5).abs() < 0.08, "std={s}");
    }

    #[test]
    fn batched_deterministic_and_clamped() {
        let a = gaussian_solve_batched(SolverMode::Sde, 500, 30);
        let b = gaussian_solve_batched(SolverMode::Sde, 500, 30);
        assert_eq!(a, b);
        for &v in &a {
            assert!((-2.0..=4.0).contains(&v));
        }
    }

    #[test]
    fn batched_update_bitwise_across_exec_contexts() {
        // per-lane noise-DAC streams make the lane-chunked integrator
        // update bitwise equal to serial at any thread count, ODE and SDE
        use crate::exec::{Ctx, ParStrategy, Pool};
        use std::sync::Arc;
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        for mode in [SolverMode::Ode, SolverMode::Sde] {
            let ctxs = [
                Ctx::serial(),
                Ctx::with_pool(ParStrategy::Lanes, Arc::new(Pool::new(1))),
                Ctx::with_pool(ParStrategy::Lanes, Arc::new(Pool::new(4))),
            ];
            let outs: Vec<Vec<f32>> = ctxs
                .into_iter()
                .map(|ctx| {
                    let cfg = SolverConfig::new(mode).with_substeps(120);
                    let solver = AnalogSolver::new(&net, cfg).with_exec(ctx);
                    let mut rng = Rng::new(21);
                    solver.solve_batched(9, &[], &mut rng)
                })
                .collect();
            assert_eq!(outs[0], outs[1], "{mode:?} 1-thread pool");
            assert_eq!(outs[0], outs[2], "{mode:?} 4-thread pool");
        }
    }
}
