//! Behavioural op-amp model (TI OPAx171 class) and derived stages.
//!
//! The solver's accuracy claims rest on the loop being much slower than
//! the amplifiers: OPA171 GBW ≈ 3 MHz while the solve trajectory bandwidth
//! is ~kHz (1 s PCB solve) — a ratio of >1000.  The single-pole model lets
//! the tests *verify* that assumption rather than assume it.

/// Op-amp parameters (defaults: OPA171 datasheet values, software units).
#[derive(Debug, Clone)]
pub struct OpampParams {
    /// Open-loop DC gain (V/V).
    pub open_loop_gain: f32,
    /// Gain-bandwidth product in Hz.
    pub gbw_hz: f64,
    /// Output saturation in software units (±; supply-limited).
    pub v_sat: f32,
    /// Input offset voltage in software units.
    pub v_offset: f32,
}

impl Default for OpampParams {
    fn default() -> Self {
        OpampParams {
            open_loop_gain: 1.0e5,
            gbw_hz: 3.0e6,
            v_sat: 120.0,      // ±12 V supply ⇒ ±120 software units
            v_offset: 0.0025,  // 0.25 mV typical ⇒ 0.0025 units
        }
    }
}

/// A closed-loop amplifier stage with first-order settling.
///
/// `target(t)` is the ideal closed-loop output; `step(dt)` relaxes the
/// actual output toward it with time constant `1 / (2π · f_closed)` where
/// `f_closed = gbw / closed_loop_gain`.
#[derive(Debug, Clone)]
pub struct Stage {
    params: OpampParams,
    closed_loop_gain: f32,
    /// Current (settled) output.
    pub v_out: f32,
}

impl Stage {
    pub fn new(params: OpampParams, closed_loop_gain: f32) -> Self {
        Stage { params, closed_loop_gain: closed_loop_gain.abs().max(1.0), v_out: 0.0 }
    }

    /// Closed-loop bandwidth in Hz.
    pub fn bandwidth_hz(&self) -> f64 {
        self.params.gbw_hz / self.closed_loop_gain as f64
    }

    /// Ideal (infinitely fast) output for input `v_in`, including offset
    /// and saturation.
    pub fn ideal(&self, v_in: f32) -> f32 {
        ((v_in + self.params.v_offset) * self.closed_loop_gain)
            .clamp(-self.params.v_sat, self.params.v_sat)
    }

    /// Advance the stage by `dt` seconds toward the ideal response.
    pub fn step(&mut self, v_in: f32, dt_s: f64) -> f32 {
        let target = self.ideal(v_in);
        let tau = 1.0 / (2.0 * std::f64::consts::PI * self.bandwidth_hz());
        let alpha = 1.0 - (-dt_s / tau).exp();
        self.v_out += alpha as f32 * (target - self.v_out);
        self.v_out
    }
}

/// Transimpedance amplifier: current (mS·V units) → voltage, gain in
/// kΩ-equivalent software units.  Saturates at the supply.
#[derive(Debug, Clone)]
pub struct Tia {
    pub gain: f32,
    pub params: OpampParams,
}

impl Tia {
    pub fn new(gain: f32) -> Self {
        Tia { gain, params: OpampParams::default() }
    }

    /// Instantaneous conversion (the loop simulation treats TIAs as fast).
    #[inline]
    pub fn convert(&self, i_in: f32) -> f32 {
        (i_in * self.gain + self.params.v_offset)
            .clamp(-self.params.v_sat, self.params.v_sat)
    }
}

/// Weighted summing amplifier: v_out = Σ w_i v_i (inverting pairs cancel).
#[derive(Debug, Clone)]
pub struct SummingAmp {
    pub weights: Vec<f32>,
    pub params: OpampParams,
}

impl SummingAmp {
    pub fn new(weights: Vec<f32>) -> Self {
        SummingAmp { weights, params: OpampParams::default() }
    }

    pub fn sum(&self, inputs: &[f32]) -> f32 {
        debug_assert_eq!(inputs.len(), self.weights.len());
        let s: f32 = inputs.iter().zip(&self.weights).map(|(v, w)| v * w).sum();
        (s + self.params.v_offset).clamp(-self.params.v_sat, self.params.v_sat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_settles_to_ideal() {
        let mut s = Stage::new(OpampParams::default(), 10.0);
        // closed-loop bw = 300 kHz; settle for 100 µs >> tau
        for _ in 0..1000 {
            s.step(0.5, 1e-7);
        }
        assert!((s.v_out - s.ideal(0.5)).abs() < 1e-3);
    }

    #[test]
    fn stage_bandwidth_scales_with_gain() {
        let lo = Stage::new(OpampParams::default(), 1.0);
        let hi = Stage::new(OpampParams::default(), 100.0);
        assert!((lo.bandwidth_hz() / hi.bandwidth_hz() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn stage_saturates() {
        let s = Stage::new(OpampParams::default(), 100.0);
        assert_eq!(s.ideal(10.0), s.params.v_sat);
        assert_eq!(s.ideal(-10.0), -s.params.v_sat);
    }

    #[test]
    fn solver_bandwidth_assumption_holds() {
        // The paper's loop: gains ≤ ~100, so closed-loop bw ≥ 30 kHz,
        // while the 1 s solve has ~kHz content ⇒ ratio ≥ 30; the projected
        // 20 µs solve scales both, keeping the ratio.
        let worst = Stage::new(OpampParams::default(), 120.0);
        assert!(worst.bandwidth_hz() > 2.0e4);
    }

    #[test]
    fn tia_linear_until_sat() {
        let t = Tia::new(25.0);
        let a = t.convert(0.1);
        let b = t.convert(0.2);
        assert!(((b - t.params.v_offset) - 2.0 * (a - t.params.v_offset)).abs() < 1e-5);
        assert_eq!(t.convert(100.0), t.params.v_sat);
    }

    #[test]
    fn summing_amp_weighted_sum() {
        let s = SummingAmp::new(vec![1.0, -2.0, 0.5]);
        let out = s.sum(&[1.0, 1.0, 2.0]);
        assert!((out - (1.0 - 2.0 + 1.0 + s.params.v_offset)).abs() < 1e-6);
    }
}
