//! AD633 four-quadrant analog multiplier (paper Fig. 2j).
//!
//! Transfer: W = (X1−X2)(Y1−Y2)/10V + Z.  In software units (0.1 V == 1)
//! the divide-by-10V becomes a divide-by-100; the solver folds that into
//! the predetermined waveform amplitudes, so the multiplier here exposes a
//! `scale` that the calibration sets.  Includes the datasheet's ±1% gain
//! error and output saturation.

/// AD633 behavioural model.
#[derive(Debug, Clone)]
pub struct Multiplier {
    /// Effective scale k in `out = k · x · y` (calibrated).
    pub scale: f32,
    /// Multiplicative gain error (datasheet ±1% typ → default 0: the PCB
    /// calibrates it out; set nonzero for sensitivity ablations).
    pub gain_error: f32,
    /// Output saturation (software units).
    pub v_sat: f32,
}

impl Multiplier {
    pub fn new(scale: f32) -> Self {
        Multiplier { scale, gain_error: 0.0, v_sat: 120.0 }
    }

    pub fn with_gain_error(mut self, e: f32) -> Self {
        self.gain_error = e;
        self
    }

    /// out = scale·(1+err)·x·y, saturated.
    #[inline]
    pub fn mul(&self, x: f32, y: f32) -> f32 {
        (self.scale * (1.0 + self.gain_error) * x * y).clamp(-self.v_sat, self.v_sat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_quadrant() {
        let m = Multiplier::new(1.0);
        assert_eq!(m.mul(2.0, 3.0), 6.0);
        assert_eq!(m.mul(-2.0, 3.0), -6.0);
        assert_eq!(m.mul(-2.0, -3.0), 6.0);
        assert_eq!(m.mul(2.0, -3.0), -6.0);
    }

    #[test]
    fn gain_error_applies() {
        let m = Multiplier::new(1.0).with_gain_error(0.01);
        assert!((m.mul(1.0, 1.0) - 1.01).abs() < 1e-6);
    }

    #[test]
    fn saturates() {
        let m = Multiplier::new(1.0);
        assert_eq!(m.mul(100.0, 100.0), m.v_sat);
        assert_eq!(m.mul(-100.0, 100.0), -m.v_sat);
    }

    #[test]
    fn zero_annihilates() {
        let m = Multiplier::new(3.7);
        assert_eq!(m.mul(0.0, 5.0), 0.0);
        assert_eq!(m.mul(5.0, 0.0), 0.0);
    }
}
