//! End-to-end integration: the full coordinator path over every engine,
//! generation quality gates, and mode-switch behaviour under load.

use std::sync::Arc;

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::service::{AnalogEngine, HloEngine, RustDigitalEngine};
use memdiff::coordinator::{Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::rng::Rng;
use memdiff::util::stats;
use memdiff::vae::{DecoderWeights, PixelDecoder};

fn artifacts_ready() -> bool {
    let ok = Meta::artifacts_dir().join("meta.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    ok
}

fn truth() -> Vec<f32> {
    let mut rng = Rng::new(31415);
    sample_circle(30_000, &mut rng)
}

/// Quality gate shared by the engine tests: the generated circle must be
/// recognizably the target distribution (KL well below a N(0,I) baseline,
/// which scores ~1.5 on this binning).
const KL_GATE: f64 = 0.9;

#[test]
fn analog_engine_generates_circle() {
    if !artifacts_ready() {
        return;
    }
    let meta = Meta::load_default().unwrap();
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json")).unwrap();
    let engine = Arc::new(AnalogEngine::new(
        AnalogScoreNet::from_conductances(
            &w, CellParams::default(), NoiseModel::ReadFast),
        meta.sched,
        1000,
    ));
    let svc = Service::start(engine, None, ServiceConfig::default());
    let r = svc
        .generate(TaskKind::Circle, 800, SolverChoice::AnalogSde, 0.0, false)
        .unwrap();
    let kl = stats::kl_points(&r.samples, &truth(), 24, 2.0);
    assert!(kl < KL_GATE, "analog KL {kl}");
    svc.shutdown();
}

#[test]
fn rust_digital_engine_generates_circle() {
    if !artifacts_ready() {
        return;
    }
    let meta = Meta::load_default().unwrap();
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json")).unwrap();
    let engine = Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(w),
        sched: meta.sched,
    });
    let svc = Service::start(engine, None, ServiceConfig::default());
    let r = svc
        .generate(TaskKind::Circle, 800,
                  SolverChoice::DigitalSde { steps: 150 }, 0.0, false)
        .unwrap();
    let kl = stats::kl_points(&r.samples, &truth(), 24, 2.0);
    assert!(kl < KL_GATE, "digital KL {kl}");
    svc.shutdown();
}

#[test]
fn hlo_engine_generates_circle() {
    if !artifacts_ready() {
        return;
    }
    // skips cleanly in the default (pjrt-stub) build, where the runtime
    // constructor errors even when artifacts exist
    let store = match ArtifactStore::open_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e})");
            return;
        }
    };
    let engine = Arc::new(HloEngine { n_classes: store.meta().n_classes, store });
    let svc = Service::start(engine, None, ServiceConfig::default());
    let r = svc
        .generate(TaskKind::Circle, 512,
                  SolverChoice::DigitalSde { steps: 150 }, 0.0, false)
        .unwrap();
    let kl = stats::kl_points(&r.samples, &truth(), 24, 2.0);
    assert!(kl < KL_GATE, "hlo KL {kl}");
    svc.shutdown();
}

#[test]
fn conditional_generation_separates_classes() {
    if !artifacts_ready() {
        return;
    }
    let meta = Meta::load_default().unwrap();
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_cond.json")).unwrap();
    let decoder = Arc::new(PixelDecoder::new(
        DecoderWeights::load(Meta::artifacts_dir().join("vae_decoder.json")).unwrap()));
    let engine = Arc::new(AnalogEngine::new(
        AnalogScoreNet::from_conductances(
            &w, CellParams::default(), NoiseModel::ReadFast),
        meta.sched,
        1000,
    ));
    let svc = Service::start(engine, Some(decoder), ServiceConfig::default());
    let mut means = Vec::new();
    for c in 0..3 {
        let r = svc
            .generate(TaskKind::Letter(c), 300, SolverChoice::AnalogSde, 2.0, true)
            .unwrap();
        let xs: Vec<f32> = r.samples.iter().step_by(2).copied().collect();
        let ys: Vec<f32> = r.samples.iter().skip(1).step_by(2).copied().collect();
        means.push([stats::mean(&xs), stats::mean(&ys)]);
        // decoded images present and in range
        let imgs = r.images.unwrap();
        assert_eq!(imgs.len(), 300 * 144);
        assert!(imgs.iter().all(|&p| (-1.0..=1.0).contains(&p)));
        // generated mean lands near this class's latent mean
        let m = meta.latent_class_means[c];
        let d = ((means[c][0] - m[0] as f64).powi(2)
            + (means[c][1] - m[1] as f64).powi(2))
            .sqrt();
        assert!(d < 0.8, "class {c}: generated mean {:?} vs {:?}", means[c], m);
    }
    // classes pairwise separated
    for i in 0..3 {
        for j in (i + 1)..3 {
            let d = ((means[i][0] - means[j][0]).powi(2)
                + (means[i][1] - means[j][1]).powi(2))
                .sqrt();
            assert!(d > 0.8, "classes {i},{j} too close: {d}");
        }
    }
    svc.shutdown();
}

#[test]
fn ode_and_sde_solvers_agree_on_distribution() {
    if !artifacts_ready() {
        return;
    }
    let meta = Meta::load_default().unwrap();
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json")).unwrap();
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let mut rng = Rng::new(3);
    let t = truth();
    let mut kls = Vec::new();
    for mode in [SolverMode::Ode, SolverMode::Sde] {
        let solver = AnalogSolver::new(&net, SolverConfig::new(mode)
            .with_schedule(meta.sched).with_substeps(1000));
        let gen = solver.solve_batch(800, &[], &mut rng);
        kls.push(stats::kl_points(&gen, &t, 24, 2.0));
    }
    assert!(kls[0] < 1.2 && kls[1] < KL_GATE, "ODE/SDE KLs {kls:?}");
}

#[test]
fn programming_mode_blocks_and_resumes() {
    if !artifacts_ready() {
        return;
    }
    let meta = Meta::load_default().unwrap();
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json")).unwrap();
    let engine = Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(w),
        sched: meta.sched,
    });
    let svc = Arc::new(Service::start(engine, None, ServiceConfig {
        workers: 2,
        batcher: BatcherConfig::default(),
        seed: 5,
        intra_threads: 0,
    }));
    // hold programming mode, fire requests, release — all must complete
    let svc2 = Arc::clone(&svc);
    let rxs: Vec<_> = {
        let _prog = svc.mode_gate.programming();
        (0..4)
            .map(|_| {
                svc2.submit(memdiff::coordinator::GenRequest {
                    id: 0,
                    task: TaskKind::Circle,
                    n_samples: 16,
                    solver: SolverChoice::DigitalSde { steps: 30 },
                    trace: memdiff::obs::TraceId::NONE,
                    guidance: 0.0,
                    decode: false,
                })
                .unwrap()
            })
            .collect()
        // _prog drops here: compute resumes
    };
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert_eq!(r.samples.len(), 32);
    }
}
