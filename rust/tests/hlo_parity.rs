//! Cross-language integration: the AOT PJRT artifacts must compute the
//! same function as the rust-native implementations.
//!
//! This is the test that catches interchange bugs — it already caught the
//! HLO printer eliding large constants (`constant({...})`) which the 0.5.1
//! text parser silently read as zeros, and the `source_end_line` metadata
//! the old parser rejects.
//!
//! Skips (cleanly passes) when `make artifacts` has not run.

use memdiff::crossbar::NoiseModel;
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, ScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::rng::Rng;
use memdiff::vae::{DecoderWeights, PixelDecoder};

fn store() -> Option<ArtifactStore> {
    if !Meta::artifacts_dir().join("meta.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    // also skips cleanly in the default (pjrt-stub) build, where the
    // runtime constructor errors even when artifacts exist
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: artifacts unavailable ({e})");
            None
        }
    }
}

fn ideal_net() -> AnalogScoreNet {
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json")).unwrap();
    let params = CellParams { read_noise_frac: 0.0, ..CellParams::default() };
    AnalogScoreNet::from_conductances(&w, params, NoiseModel::Ideal)
}

/// Tolerance: the remaining deltas are the rust analog net's physical
/// touches — diode soft-knee ReLU (≤ 0.014 near zero) and the 12-bit
/// embedding DAC — plus f32 reassociation across XLA versions.
const TOL: f32 = 3e-2;

#[test]
fn score_artifact_matches_rust_conductance_net() {
    let Some(store) = store() else { return };
    let net = ideal_net();
    let mut rng = Rng::new(0);
    let mut out = [0.0f32; 2];
    for i in 0..20 {
        let x = [0.35 * (i as f32 - 10.0) / 10.0, 0.2 * ((i * 7 % 13) as f32 - 6.0) / 6.0];
        let t = 0.05 + 0.9 * i as f32 / 19.0;
        let hlo = store.score_uncond(1, &x, t).unwrap();
        net.eval(&x, t, &[0.0, 0.0, 0.0], &mut out, &mut rng);
        for k in 0..2 {
            assert!(
                (hlo[k] - out[k]).abs() < TOL,
                "i={i} k={k}: hlo {} vs rust {}",
                hlo[k],
                out[k]
            );
        }
    }
}

#[test]
fn step_artifact_matches_manual_composition() {
    // step(x, t, dt, mode, noise) == clamp(euler(x, score(x,t), ...))
    let Some(store) = store() else { return };
    let meta = store.meta().clone();
    let x = [0.5f32, -0.5];
    let noise = [0.25f32, -1.0];
    for (t, dt, mode) in [(0.9f32, 0.004f32, 0.0f32), (0.5, 0.01, 1.0), (0.05, 0.002, 0.0)] {
        let s = store.score_uncond(1, &x, t).unwrap();
        let beta = meta.sched.beta(t as f64) as f32;
        let sigma = meta.sched.sigma(t as f64) as f32;
        // score = -net/sigma; SDE rhs = -b/2 x + b/sigma net; ODE halves the net term
        let mut want = [0.0f32; 2];
        for k in 0..2 {
            let rhs_sde = -0.5 * beta * x[k] + beta / sigma * s[k];
            let rhs_ode = -0.5 * beta * x[k] + 0.5 * beta / sigma * s[k];
            let rhs = mode * rhs_sde + (1.0 - mode) * rhs_ode;
            let diff = mode * (beta * dt).max(0.0).sqrt();
            want[k] = (x[k] - dt * rhs + diff * noise[k]).clamp(-2.0, 4.0);
        }
        let got = store.step_uncond(1, &x, t, dt, mode, &noise).unwrap();
        for k in 0..2 {
            assert!(
                (got[k] - want[k]).abs() < 1e-4,
                "t={t} mode={mode} k={k}: {} vs {}",
                got[k],
                want[k]
            );
        }
    }
}

#[test]
fn cond_step_cfg_reduces_to_uncond_at_lambda_zero_null_token() {
    // with an all-zero onehot, conditional and unconditional nets see the
    // same embedding; CFG combine is (1+λ)s - λs = s for any λ then
    let Some(store) = store() else { return };
    let x = [0.2f32, 0.1];
    let noise = [0.0f32, 0.0];
    let onehot = [0.0f32, 0.0, 0.0];
    let a = store
        .step_cond(1, &x, 0.5, 0.01, 0.0, &noise, &onehot, 0.0)
        .unwrap();
    let b = store
        .step_cond(1, &x, 0.5, 0.01, 0.0, &noise, &onehot, 2.0)
        .unwrap();
    for k in 0..2 {
        assert!((a[k] - b[k]).abs() < 1e-5, "{} vs {}", a[k], b[k]);
    }
}

#[test]
fn cond_step_condition_changes_output() {
    let Some(store) = store() else { return };
    let x = [0.2f32, 0.1];
    let noise = [0.0f32, 0.0];
    let a = store
        .step_cond(1, &x, 0.5, 0.01, 0.0, &noise, &[1.0, 0.0, 0.0], 2.0)
        .unwrap();
    let b = store
        .step_cond(1, &x, 0.5, 0.01, 0.0, &noise, &[0.0, 0.0, 1.0], 2.0)
        .unwrap();
    assert!((a[0] - b[0]).abs() + (a[1] - b[1]).abs() > 1e-5);
}

#[test]
fn decoder_artifact_matches_rust_decoder() {
    let Some(store) = store() else { return };
    let dec = PixelDecoder::new(
        DecoderWeights::load(Meta::artifacts_dir().join("vae_decoder.json")).unwrap(),
    );
    for z in [[0.0f32, 0.0], [1.2, -0.7], [-1.5, 1.5]] {
        let hlo = store.decode(1, &z).unwrap();
        let rust = dec.decode(&z);
        assert_eq!(hlo.len(), 144);
        for k in 0..144 {
            assert!(
                (hlo[k] - rust[k]).abs() < 1e-4,
                "z={z:?} pix {k}: {} vs {}",
                hlo[k],
                rust[k]
            );
        }
    }
}

#[test]
fn batch_sizes_agree() {
    // the b1 and b64 lowerings of the same function must agree lane-wise
    let Some(store) = store() else { return };
    let mut x64 = vec![0.0f32; 128];
    let mut rng = Rng::new(5);
    rng.fill_gaussian(&mut x64);
    let s64 = store.score_uncond(64, &x64, 0.42).unwrap();
    for lane in [0usize, 17, 63] {
        let x1 = [x64[2 * lane], x64[2 * lane + 1]];
        let s1 = store.score_uncond(1, &x1, 0.42).unwrap();
        for k in 0..2 {
            assert!(
                (s1[k] - s64[2 * lane + k]).abs() < 1e-5,
                "lane {lane} k={k}"
            );
        }
    }
}

#[test]
fn hlo_text_has_no_elided_constants() {
    // regression guard for the constant({...}) corruption
    let Some(store) = store() else { return };
    for spec in store.meta().artifacts.values() {
        let text =
            std::fs::read_to_string(Meta::artifacts_dir().join(&spec.file)).unwrap();
        assert!(
            !text.contains("{...}"),
            "{} contains elided constants",
            spec.file
        );
        assert!(
            !text.contains("source_end_line"),
            "{} contains metadata the 0.5.1 parser rejects",
            spec.file
        );
    }
}
