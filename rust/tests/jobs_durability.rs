//! Durable-job-queue crash suite: torn-log replay at every byte offset,
//! SIGKILL + restart end-to-end over the TCP front-end (the acceptance
//! scenario — every fsync-acknowledged job is re-run or its retained
//! result served), and the drain regression (a runner drain checkpoints
//! queued work instead of dropping it, without burning retry budget).
//!
//! Runs without AOT artifacts (synthetic weights / stub engines).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::service::Engine;
use memdiff::coordinator::{
    GenRequest, Service, ServiceConfig, SolverChoice, TaskKind,
};
use memdiff::jobs::{record, JobRunner, JobState, JobStore, RunnerConfig};
use memdiff::util::rng::Rng;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("memdiff_jobsit_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn req(n: usize) -> GenRequest {
    GenRequest {
        id: 0,
        task: TaskKind::Circle,
        n_samples: n,
        solver: SolverChoice::DigitalOde { steps: 8 },
        trace: memdiff::obs::TraceId::NONE,
        guidance: 0.0,
        decode: false,
    }
}

// ------------------------------------------------- torn-tail replay

/// Property test over the record framing as the store actually uses it:
/// truncate `jobs.log` at EVERY byte offset and reopen.  Replay must
/// never fail, must recover exactly the complete-frame prefix (the
/// fsync-acknowledged jobs), and must drop only the torn tail.
#[test]
fn log_truncated_at_every_offset_replays_exact_acknowledged_prefix() {
    let dir = tmp("trunc");
    let store = JobStore::open(&dir).unwrap();
    const N: u64 = 6;
    for i in 0..N {
        let id = store.enqueue(&req(1 + i as usize), 0, 2, 60_000).unwrap();
        assert_eq!(id, i + 1, "ids are dense from 1");
    }
    drop(store);
    let log = std::fs::read(dir.join("jobs.log")).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let cut_dir = tmp("trunc_cut");
    for cut in 0..=log.len() {
        std::fs::create_dir_all(&cut_dir).unwrap();
        std::fs::write(cut_dir.join("jobs.log"), &log[..cut]).unwrap();
        // the codec is the oracle: a job survives iff its frame is whole
        let (frames, clean) = record::decode_all(&log[..cut]);
        assert!(clean <= cut);
        let replayed = JobStore::open(&cut_dir)
            .unwrap_or_else(|e| panic!("cut at {cut}: replay failed: {e:#}"));
        let g = replayed.gauges();
        assert_eq!(g.queued, frames.len(), "cut at {cut}");
        assert_eq!(g.enqueued_total, frames.len() as u64, "cut at {cut}");
        for id in 1..=frames.len() as u64 {
            let j = replayed.get(id).unwrap_or_else(|| {
                panic!("cut at {cut}: job {id} lost from clean prefix")
            });
            assert_eq!(j.state, JobState::Queued);
            assert_eq!(j.n_samples, id as usize, "payload intact at cut {cut}");
        }
        assert!(replayed.get(frames.len() as u64 + 1).is_none(),
                "cut at {cut}: torn tail must not materialize a job");
        drop(replayed);
        std::fs::remove_dir_all(&cut_dir).unwrap();
    }
}

// ---------------------------------------------- SIGKILL + restart e2e

#[cfg(unix)]
mod sigkill {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::process::{Child, Command, Stdio};

    use memdiff::serve::protocol::{self, read_reply, Status};

    fn spawn_server(dir: &Path) -> (Child, String) {
        let mut child = Command::new(env!("CARGO_BIN_EXE_memdiff"))
            .args(["serve", "--listen", "127.0.0.1:0", "--synthetic",
                   "--workers", "1", "--state-dir"])
            .arg(dir)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn memdiff serve");
        let stdout = child.stdout.take().unwrap();
        let mut lines = BufReader::new(stdout);
        let addr = loop {
            let mut line = String::new();
            assert!(lines.read_line(&mut line).unwrap() > 0,
                    "server exited before listening");
            if let Some(a) = line.trim().strip_prefix("listening on ") {
                break a.to_string();
            }
        };
        // keep the pipe drained so the child never blocks on stdout
        std::thread::spawn(move || {
            let mut s = String::new();
            while matches!(lines.read_line(&mut s), Ok(n) if n > 0) {
                s.clear();
            }
        });
        (child, addr)
    }

    fn connect(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        (stream.try_clone().unwrap(), BufReader::new(stream))
    }

    fn send(w: &mut TcpStream, line: &str) {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
    }

    /// The acceptance scenario: enqueue over loopback, SIGKILL the
    /// server, restart on the same state dir, and fetch every
    /// acknowledged job — the completed one's retained result is served
    /// and the interrupted one is re-run to `done`.  Zero silent losses.
    #[test]
    fn sigkill_and_restart_serves_every_acknowledged_job() {
        let dir = tmp("kill");
        let (mut child, addr) = spawn_server(&dir);
        let (mut w, mut r) = connect(&addr);

        // job A: run to completion before the crash (retained result)
        send(&mut w, &protocol::enqueue_line(
            1, TaskKind::Circle, 2, SolverChoice::DigitalOde { steps: 8 },
            0.0, false, 0, None, None));
        let ack = read_reply(&mut r).unwrap();
        assert_eq!(ack.status, Status::Ok, "{:?}", ack.error);
        let job_a = ack.job.expect("enqueue ack carries the job id");
        send(&mut w, &protocol::result_line(2, job_a, 30_000));
        let done = read_reply(&mut r).unwrap();
        assert_eq!((done.status, done.state.as_deref()),
                   (Status::Ok, Some("done")), "{:?}", done.error);
        assert_eq!(done.samples.len(), 2 * done.dim);

        // job B: acknowledged (fsync'd) right before the kill
        send(&mut w, &protocol::enqueue_line(
            3, TaskKind::Letter(1), 3, SolverChoice::DigitalSde { steps: 8 },
            0.0, false, 0, None, None));
        let ack_b = read_reply(&mut r).unwrap();
        assert_eq!(ack_b.status, Status::Ok, "{:?}", ack_b.error);
        let job_b = ack_b.job.unwrap();
        assert_ne!(job_a, job_b);

        child.kill().unwrap();
        child.wait().unwrap();
        drop((w, r));

        // restart on the same state dir: the log replays
        let (mut child2, addr2) = spawn_server(&dir);
        let (mut w, mut r) = connect(&addr2);
        for (k, job) in [job_a, job_b].into_iter().enumerate() {
            send(&mut w, &protocol::result_line(10 + k as u64, job, 30_000));
            let reply = read_reply(&mut r).unwrap();
            assert_eq!(reply.job, Some(job));
            assert_eq!((reply.status, reply.state.as_deref()),
                       (Status::Ok, Some("done")),
                       "job {job} after restart: {:?}", reply.error);
            assert!(!reply.samples.is_empty(), "job {job} payload served");
        }

        // graceful exit this time: drain checkpoints the store
        send(&mut w, &protocol::shutdown_line());
        assert_eq!(read_reply(&mut r).unwrap().status, Status::Ok);
        child2.wait().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}

// --------------------------------------------------- drain regression

/// Engine blocked on a shared gate: pins the attempt in flight while the
/// test drains the runner underneath it.
struct GateEngine {
    gate: Arc<Mutex<()>>,
    entered: Arc<AtomicUsize>,
}

impl Engine for GateEngine {
    fn dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        3
    }
    fn generate(&self, _s: SolverChoice, _oh: &[f32], _g: f32, n: usize,
                _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        let _hold = self.gate.lock().unwrap();
        Ok(vec![0.5; n * 2])
    }
}

struct OkEngine;

impl Engine for OkEngine {
    fn dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        3
    }
    fn generate(&self, _s: SolverChoice, _oh: &[f32], _g: f32, n: usize,
                _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        Ok(vec![0.25; n * 2])
    }
}

fn svc(engine: Arc<dyn Engine>) -> Arc<Service> {
    Arc::new(Service::start(engine, None, ServiceConfig {
        workers: 1,
        batcher: BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(1),
            queue_depth: 0,
        },
        seed: 0xD12A,
        intra_threads: 1,
    }))
}

/// Regression for the shutdown/drain interaction: draining the runner
/// while attempts are in flight must checkpoint those jobs as `queued`
/// (not failed, not dropped, no retry budget burned), and a fresh
/// runner on the same state dir must complete every one of them.
#[test]
fn runner_drain_checkpoints_inflight_jobs_and_restart_completes_them() {
    let dir = tmp("drain");
    let gate = Arc::new(Mutex::new(()));
    let entered = Arc::new(AtomicUsize::new(0));
    let service = svc(Arc::new(GateEngine {
        gate: Arc::clone(&gate),
        entered: Arc::clone(&entered),
    }));
    let store = Arc::new(JobStore::open(&dir).unwrap());
    let runner = JobRunner::start(
        Arc::clone(&service),
        Arc::clone(&store),
        RunnerConfig {
            sweep_interval: Duration::from_millis(20),
            drain_grace: Duration::from_millis(200),
            ..RunnerConfig::default()
        },
    );

    // pin the worker inside generate(), then get three jobs in flight
    let hold = gate.lock().unwrap();
    let ids: Vec<u64> = (0..3)
        .map(|_| runner.enqueue(&req(2), 0, None, None).unwrap())
        .collect();
    while entered.load(Ordering::SeqCst) == 0 {
        std::thread::yield_now();
    }

    // drain with everything stuck: after the grace window the runner
    // must requeue the in-flight attempts and checkpoint them durably
    runner.drain();
    drop(runner);
    drop(hold); // let the abandoned batches finish; their tickets are gone
    drop(service); // Drop drains the service under the no-drop invariant
    drop(store);

    let store2 = Arc::new(JobStore::open(&dir).unwrap());
    let g = store2.gauges();
    assert_eq!((g.queued, g.done, g.dead, g.failed), (3, 0, 0, 0),
               "drain parks jobs as queued: {}", g.summary());
    for id in &ids {
        let j = store2.get(*id).expect("no job dropped across drain");
        assert_eq!(j.state, JobState::Queued);
        assert_eq!(j.attempts, 0, "a drain is not a failed attempt");
    }

    // fresh runner over a healthy engine: every parked job completes
    let service2 = svc(Arc::new(OkEngine));
    let runner2 = JobRunner::start(
        Arc::clone(&service2),
        Arc::clone(&store2),
        RunnerConfig {
            sweep_interval: Duration::from_millis(20),
            ..RunnerConfig::default()
        },
    );
    for id in ids {
        let j = runner2
            .wait_result(id, Duration::from_secs(30))
            .expect("job resolves after restart");
        assert_eq!(j.state, JobState::Done, "job {id}: {:?}", j.error);
        let result = j.result.expect("done job retains its result");
        assert_eq!(result.samples, vec![0.25; 4]);
    }
    runner2.drain();
    drop(runner2);
    drop(service2);
    std::fs::remove_dir_all(&dir).ok();
}
