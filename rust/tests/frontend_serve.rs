//! Async front-end integration suite: bounded-queue reject semantics per
//! lane, ticket completion vs. the blocking `submit` oracle (bitwise),
//! shutdown drain under concurrent in-flight tickets, and the TCP
//! front-end end-to-end (wire protocol, overload statuses, connection
//! cap, graceful drain).
//!
//! Runs without AOT artifacts (synthetic weights / stub engines).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::service::Engine;
use memdiff::coordinator::{
    EngineRegistry, GenRequest, GenResponse, Service, ServiceConfig,
    SolverChoice, SolverFamily, SubmitError, TaskKind,
};
use memdiff::crossbar::NoiseModel;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::schedule::VpSchedule;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::serve::protocol::{self, Status};
use memdiff::serve::{FrontEnd, FrontEndConfig, WireReply};
use memdiff::util::rng::Rng;

// ---------------------------------------------------------------- engines

/// Constant-tag engine: proves which backend served a request.
struct TagEngine(f32);

impl Engine for TagEngine {
    fn dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        3
    }
    fn generate(&self, _s: SolverChoice, _oh: &[f32], _g: f32, n: usize,
                _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        Ok(vec![self.0; n * 2])
    }
}

/// Engine blocked on a shared gate: holds a worker busy deterministically
/// while a test fills the lane queue behind it.
struct GateEngine {
    gate: Arc<Mutex<()>>,
    entered: Arc<AtomicUsize>,
}

impl Engine for GateEngine {
    fn dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        3
    }
    fn generate(&self, _s: SolverChoice, _oh: &[f32], _g: f32, n: usize,
                _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        let _hold = self.gate.lock().unwrap();
        Ok(vec![0.0; n * 2])
    }
}

/// Engine stamping each batch with its global serving order, so a test
/// can assert FIFO completion per lane.
struct SeqEngine {
    ctr: AtomicU32,
}

impl Engine for SeqEngine {
    fn dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        3
    }
    fn generate(&self, _s: SolverChoice, _oh: &[f32], _g: f32, n: usize,
                _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        let seq = self.ctr.fetch_add(1, Ordering::SeqCst) as f32;
        Ok(vec![seq; n * 2])
    }
}

// ----------------------------------------------------------------- setup

fn weights() -> ScoreWeights {
    ScoreWeights::synthetic(2, 8, 3, 77)
}

fn analog_engine(noise: NoiseModel) -> Arc<dyn Engine> {
    use memdiff::coordinator::service::AnalogEngine;
    let params = if matches!(noise, NoiseModel::Ideal) {
        CellParams { read_noise_frac: 0.0, ..CellParams::default() }
    } else {
        CellParams::default()
    };
    Arc::new(AnalogEngine::new(
        AnalogScoreNet::from_conductances(&weights(), params, noise),
        VpSchedule::default(),
        30,
    ))
}

fn rust_engine() -> Arc<dyn Engine> {
    use memdiff::coordinator::service::RustDigitalEngine;
    Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(weights()),
        sched: VpSchedule::default(),
    })
}

fn svc_cfg(max_batch: usize, queue_depth: usize) -> ServiceConfig {
    ServiceConfig {
        workers: 1,
        batcher: BatcherConfig {
            max_batch_samples: max_batch,
            linger: Duration::from_millis(1),
            queue_depth,
        },
        seed: 0xF0F0,
        intra_threads: 1,
    }
}

/// Two-lane routed deployment over the synthetic engines.
fn routed(noise: NoiseModel) -> Service {
    let mut reg = EngineRegistry::new();
    reg.add_backend("analog", analog_engine(noise), 1).unwrap();
    reg.add_backend("rust", rust_engine(), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    Service::start_routed(reg, None, svc_cfg(64, 0))
}

fn req(task: TaskKind, solver: SolverChoice, n: usize) -> GenRequest {
    GenRequest { id: 0, task, n_samples: n, solver, guidance: 2.0, decode: false,
                 trace: memdiff::obs::TraceId::NONE }
}

fn scenario() -> Vec<GenRequest> {
    let mut out = Vec::new();
    for r in 0..3usize {
        out.push(req(TaskKind::Circle, SolverChoice::AnalogOde, 3 + r));
        out.push(req(TaskKind::Letter(r % 3), SolverChoice::AnalogSde, 2 + r));
        out.push(req(TaskKind::Circle,
                     SolverChoice::DigitalOde { steps: 12 }, 4 + r));
        out.push(req(TaskKind::Letter((r + 1) % 3),
                     SolverChoice::DigitalSde { steps: 12 }, 3 + r));
    }
    out
}

// ------------------------------------------------- per-lane backpressure

/// Fill one bounded lane while its worker is held busy: that lane sheds
/// `Overloaded` without blocking the caller, the *other* lane keeps
/// serving, and every accepted ticket still completes.
#[test]
fn full_lane_sheds_while_other_lane_serves() {
    let gate = Arc::new(Mutex::new(()));
    let entered = Arc::new(AtomicUsize::new(0));
    let mut reg = EngineRegistry::new();
    // analog lane: gated engine, bounded to 4 samples
    reg.add_backend_cfg(
        "slow",
        Arc::new(GateEngine {
            gate: Arc::clone(&gate),
            entered: Arc::clone(&entered),
        }),
        1,
        4,
    )
    .unwrap();
    // digital lane: fast tag engine, unbounded
    reg.add_backend("fast", Arc::new(TagEngine(2.0)), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "slow").unwrap();
    reg.route_family(SolverFamily::Digital, "fast").unwrap();
    // max_batch 1: every request is its own batch (no coalescing races)
    let s = Service::start_routed(reg, None, svc_cfg(1, 0));

    // occupy the slow worker inside generate()
    let hold = gate.lock().unwrap();
    let first = s
        .submit_nb(req(TaskKind::Circle, SolverChoice::AnalogOde, 1))
        .unwrap();
    while entered.load(Ordering::SeqCst) == 0 {
        std::thread::yield_now();
    }
    // fill the slow lane to its 4-sample bound
    let queued: Vec<_> = (0..4)
        .map(|_| {
            s.submit_nb(req(TaskKind::Circle, SolverChoice::AnalogOde, 1))
                .unwrap()
        })
        .collect();
    // the next analog request is shed immediately — no blocking
    let t0 = std::time::Instant::now();
    let err = s
        .submit_nb(req(TaskKind::Circle, SolverChoice::AnalogOde, 1))
        .unwrap_err();
    assert!(t0.elapsed() < Duration::from_millis(250),
            "overload must answer without blocking");
    match &err {
        SubmitError::Overloaded {
            backend, queued_samples, queue_depth, retry_after_ms,
        } => {
            assert_eq!(backend, "slow");
            assert_eq!((*queued_samples, *queue_depth), (4, 4));
            assert!(*retry_after_ms > 0, "shed carries a backoff hint");
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }

    // the OTHER lane still serves end-to-end while the slow lane is full
    let d = s
        .generate(TaskKind::Circle, 5, SolverChoice::DigitalOde { steps: 4 },
                  0.0, false)
        .unwrap();
    assert_eq!(d.samples, vec![2.0; 10], "digital lane unaffected");

    // gauges: service total + the slow backend's reject/queue columns
    let snap = s.metrics.snapshot();
    assert_eq!(snap.rejected, 1);
    let slow = snap.backends.iter().find(|b| b.name == "slow").unwrap();
    assert_eq!(slow.rejected, 1);
    assert_eq!(slow.queue_depth, 4, "queue gauge shows the full lane");
    let fast = snap.backends.iter().find(|b| b.name == "fast").unwrap();
    assert_eq!(fast.rejected, 0);
    assert!(snap.report().contains("rej1"), "{}", snap.report());

    // release: every accepted ticket completes, nothing leaks
    drop(hold);
    assert!(first.recv().is_ok());
    for t in queued {
        assert!(t
            .recv_timeout(Duration::from_secs(30))
            .expect("accepted ticket completes")
            .is_ok());
    }
    s.shutdown();
}

// --------------------------------------------- tickets vs blocking oracle

fn assert_bitwise(a: &[GenResponse], b: &[GenResponse], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: response counts");
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.samples.len(), rb.samples.len(), "{what} req {i}");
        for (k, (x, y)) in ra.samples.iter().zip(&rb.samples).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{what} req {i} sample {k}: {x} vs {y}");
        }
    }
}

/// The ticket path is a transport, not a computation: replaying the same
/// request stream through `submit_nb` + polling must yield bitwise the
/// same payloads as the blocking `generate` oracle — per class, in Ideal
/// and noisy modes.
#[test]
fn tickets_bitwise_match_blocking_submit_oracle() {
    for (noise, what) in [(NoiseModel::Ideal, "ideal"),
                          (NoiseModel::ReadFast, "readfast")] {
        // nonblocking replay: poll each ticket to completion before the
        // next submit, so batches and RNG consumption replay exactly
        let nb = routed(noise);
        let via_tickets: Vec<GenResponse> = scenario()
            .into_iter()
            .map(|r| {
                let t = nb.submit_nb(r).unwrap();
                loop {
                    // exercise the poll path (try_recv), not recv()
                    if let Some(result) = t.try_recv() {
                        break result.unwrap();
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
            })
            .collect();
        nb.shutdown();

        // blocking oracle: same deployment, same seeds, same stream
        let oracle = routed(noise);
        let via_blocking: Vec<GenResponse> = scenario()
            .into_iter()
            .map(|r| {
                oracle
                    .generate(r.task, r.n_samples, r.solver, r.guidance,
                              r.decode)
                    .unwrap()
            })
            .collect();
        oracle.shutdown();

        assert_bitwise(&via_tickets, &via_blocking, what);
    }
}

/// Same-lane tickets complete in submission order (FIFO per lane), and a
/// deadline-wait sees them in that order.
#[test]
fn ticket_completion_order_is_fifo_per_lane() {
    let reg = EngineRegistry::single(Arc::new(SeqEngine {
        ctr: AtomicU32::new(0),
    }));
    // one worker, one request per batch: serving order == queue order
    let s = Service::start_routed(reg, None, svc_cfg(1, 0));
    let tickets: Vec<_> = (0..8)
        .map(|_| {
            s.submit_nb(req(TaskKind::Circle, SolverChoice::AnalogOde, 1))
                .unwrap()
        })
        .collect();
    let mut stamps = Vec::new();
    for t in &tickets {
        let r = t
            .recv_deadline(std::time::Instant::now() + Duration::from_secs(30))
            .expect("completes before the deadline")
            .unwrap();
        stamps.push(r.samples[0]);
    }
    let expect: Vec<f32> = (0..8).map(|k| k as f32).collect();
    assert_eq!(stamps, expect, "FIFO serving order per lane");
    s.shutdown();
}

// ------------------------------------------------------- shutdown drain

/// Queue mixed-class tickets, some with waiters already blocked on them,
/// then shut down immediately: every ticket resolves Ok (the queued work
/// drains) and no waiter is left stuck.
#[test]
fn shutdown_drains_inflight_tickets_no_stuck_waiter() {
    let mut reg = EngineRegistry::new();
    reg.add_backend("analog", Arc::new(TagEngine(1.0)), 2).unwrap();
    reg.add_backend("rust", Arc::new(TagEngine(2.0)), 2).unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    let s = Service::start_routed(reg, None, svc_cfg(64, 0));

    let mut waited = Vec::new();
    let mut polled = Vec::new();
    for (i, r) in scenario().into_iter().enumerate() {
        let t = s.submit_nb(r).unwrap();
        if i % 2 == 0 {
            // half the tickets get a blocked waiter thread right away
            waited.push(std::thread::spawn(move || t.recv()));
        } else {
            polled.push(t);
        }
    }
    // shutdown with all of that in flight: drains every lane, fails any
    // leftover ticket — so every waiter must return
    s.shutdown();
    for h in waited {
        let r = h.join().expect("waiter thread finished");
        assert!(r.is_ok(), "queued work drained: {:?}", r.err());
    }
    for t in polled {
        let r = t.try_recv().expect("resolved by shutdown at the latest");
        assert!(r.is_ok(), "{:?}", r.err());
    }
}

// ------------------------------------------------------- TCP front-end

fn read_reply(reader: &mut BufReader<TcpStream>) -> WireReply {
    protocol::read_reply(reader).expect("reply line")
}

fn send_line(w: &mut TcpStream, line: &str) {
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
}

fn tag_front(queue_depth: usize, max_conns: usize) -> FrontEnd {
    let mut reg = EngineRegistry::new();
    reg.add_backend("analog", Arc::new(TagEngine(1.0)), 1).unwrap();
    reg.add_backend("rust", Arc::new(TagEngine(2.0)), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    let s = Service::start_routed(reg, None, svc_cfg(64, queue_depth));
    FrontEnd::bind(s, "127.0.0.1:0", FrontEndConfig {
        max_conns,
        poll: Duration::from_millis(2),
        ..FrontEndConfig::default()
    })
    .unwrap()
}

#[test]
fn tcp_roundtrip_mixed_classes_and_errors() {
    let front = tag_front(0, 8);
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // two classes through one connection, out-of-order-safe via ids
    send_line(&mut w, &protocol::request_line(
        7, TaskKind::Circle, 3, SolverChoice::AnalogOde, 0.0, false));
    send_line(&mut w, &protocol::request_line(
        8, TaskKind::Letter(1), 2, SolverChoice::DigitalSde { steps: 5 },
        2.0, false));
    let mut got = std::collections::HashMap::new();
    for _ in 0..2 {
        let reply = read_reply(&mut r);
        assert_eq!(reply.status, Status::Ok, "{:?}", reply.error);
        got.insert(reply.id, reply);
    }
    let a = &got[&7];
    assert_eq!(a.dim, 2);
    assert_eq!(a.samples, vec![1.0; 6], "analog lane tag");
    let d = &got[&8];
    assert_eq!(d.samples, vec![2.0; 4], "digital lane tag");

    // malformed line and bad fields answer `error`, connection survives
    send_line(&mut w, "this is not json");
    assert_eq!(read_reply(&mut r).status, Status::Error);
    send_line(&mut w, r#"{"id": 9, "task": "zebra"}"#);
    let bad = read_reply(&mut r);
    assert_eq!((bad.id, bad.status), (9, Status::Error));
    send_line(&mut w, r#"{"id": 10, "n": 0}"#);
    assert_eq!(read_reply(&mut r).status, Status::Error, "invalid request");
    // still serving after the errors
    send_line(&mut w, &protocol::request_line(
        11, TaskKind::Circle, 1, SolverChoice::AnalogSde, 0.0, false));
    assert_eq!(read_reply(&mut r).status, Status::Ok);

    front.shutdown();
}

#[test]
fn tcp_overload_surfaces_structured_status() {
    let gate = Arc::new(Mutex::new(()));
    let entered = Arc::new(AtomicUsize::new(0));
    let mut reg = EngineRegistry::new();
    // single gated lane bounded at 2 samples; every class routes to it
    reg.add_backend_cfg(
        "gated",
        Arc::new(GateEngine {
            gate: Arc::clone(&gate),
            entered: Arc::clone(&entered),
        }),
        1,
        2,
    )
    .unwrap();
    for family in [SolverFamily::Analog, SolverFamily::Digital] {
        reg.route_family(family, "gated").unwrap();
    }
    let s = Service::start_routed(reg, None, svc_cfg(1, 0));
    let front = FrontEnd::bind(s, "127.0.0.1:0", FrontEndConfig {
        poll: Duration::from_millis(2),
        ..FrontEndConfig::default()
    })
    .unwrap();
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // hold the gate FIRST, then let id 1 occupy the worker inside
    // generate() — deterministic: the worker cannot finish early
    let hold = gate.lock().unwrap();
    send_line(&mut w, &protocol::request_line(
        1, TaskKind::Circle, 1, SolverChoice::AnalogOde, 0.0, false));
    while entered.load(Ordering::SeqCst) == 0 {
        std::thread::yield_now();
    }
    // ids 2,3 fill the 2-sample bound; 4,5 must shed as `overloaded`
    for id in 2..=5u64 {
        send_line(&mut w, &protocol::request_line(
            id, TaskKind::Circle, 1, SolverChoice::AnalogOde, 0.0, false));
    }
    let mut ok_ids = Vec::new();
    let mut shed_ids = Vec::new();
    // the two sheds answer immediately; 1..3 answer once the gate drops
    for _ in 0..2 {
        let reply = read_reply(&mut r);
        assert_eq!(reply.status, Status::Overloaded, "{:?}", reply.error);
        assert_eq!(reply.queue_depth, Some(2), "bound on the wire");
        assert_eq!(reply.queued_samples, Some(2));
        shed_ids.push(reply.id);
    }
    drop(hold);
    for _ in 0..3 {
        let reply = read_reply(&mut r);
        assert_eq!(reply.status, Status::Ok, "{:?}", reply.error);
        ok_ids.push(reply.id);
    }
    shed_ids.sort_unstable();
    ok_ids.sort_unstable();
    assert_eq!(shed_ids, vec![4, 5]);
    assert_eq!(ok_ids, vec![1, 2, 3]);

    let metrics = front.metrics();
    front.shutdown();
    let snap = metrics.snapshot();
    assert_eq!(snap.rejected, 2);
    assert_eq!(snap.backends[0].rejected, 2);
}

#[test]
fn tcp_connection_cap_rejects_at_edge() {
    let front = tag_front(0, 1);
    // first connection claims the only handler slot
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    send_line(&mut w, &protocol::request_line(
        1, TaskKind::Circle, 1, SolverChoice::AnalogOde, 0.0, false));
    assert_eq!(read_reply(&mut r).status, Status::Ok);
    // second concurrent connection is answered `overloaded` and closed
    let s2 = TcpStream::connect(front.local_addr()).unwrap();
    let mut r2 = BufReader::new(s2);
    let reply = read_reply(&mut r2);
    assert_eq!(reply.status, Status::Overloaded);
    assert!(reply.error.unwrap().contains("connection limit"));
    let mut rest = String::new();
    assert_eq!(r2.read_line(&mut rest).unwrap(), 0, "edge-rejected conn closes");
    front.shutdown();
}

/// Graceful drain end-to-end: in-flight tickets complete and are
/// delivered, new requests on live connections and brand-new connections
/// both get `shutting_down`.
#[test]
fn tcp_graceful_drain_completes_inflight() {
    let gate = Arc::new(Mutex::new(()));
    let entered = Arc::new(AtomicUsize::new(0));
    let reg = EngineRegistry::single(Arc::new(GateEngine {
        gate: Arc::clone(&gate),
        entered: Arc::clone(&entered),
    }));
    let s = Service::start_routed(reg, None, svc_cfg(1, 0));
    let front = FrontEnd::bind(s, "127.0.0.1:0", FrontEndConfig {
        poll: Duration::from_millis(2),
        ..FrontEndConfig::default()
    })
    .unwrap();
    let addr = front.local_addr();
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // hold the gate first, then put one request in flight (worker
    // blocked inside generate until the test releases it)
    let hold = gate.lock().unwrap();
    send_line(&mut w, &protocol::request_line(
        1, TaskKind::Circle, 2, SolverChoice::AnalogOde, 0.0, false));
    while entered.load(Ordering::SeqCst) == 0 {
        std::thread::yield_now();
    }

    front.request_drain();
    // a new request on the live connection: shutting_down
    send_line(&mut w, &protocol::request_line(
        2, TaskKind::Circle, 1, SolverChoice::AnalogOde, 0.0, false));
    let reply = read_reply(&mut r);
    assert_eq!((reply.id, reply.status), (2, Status::ShuttingDown));
    // a brand-new connection: one shutting_down line, then closed
    {
        let s2 = TcpStream::connect(addr).unwrap();
        let mut r2 = BufReader::new(s2);
        assert_eq!(read_reply(&mut r2).status, Status::ShuttingDown);
        let mut rest = String::new();
        assert_eq!(r2.read_line(&mut rest).unwrap(), 0);
    }

    // release the worker: the in-flight ticket completes AND is delivered
    drop(hold);
    let reply = read_reply(&mut r);
    assert_eq!((reply.id, reply.status), (1, Status::Ok));
    assert_eq!(reply.samples.len(), 4);
    // connection then closes (drained handler)
    let mut rest = String::new();
    assert_eq!(r.read_line(&mut rest).unwrap(), 0, "handler closes after drain");

    // full shutdown joins cleanly under the no-dropped-request invariant
    front.shutdown();
}

/// The `{"op":"shutdown"}` control line drives the same drain from the
/// client side (what `memdiff client --shutdown` and the CI smoke use).
#[test]
fn tcp_client_shutdown_op_drains_server() {
    let front = tag_front(0, 4);
    let addr = front.local_addr();
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    send_line(&mut w, &protocol::request_line(
        1, TaskKind::Circle, 1, SolverChoice::AnalogOde, 0.0, false));
    assert_eq!(read_reply(&mut r).status, Status::Ok);
    send_line(&mut w, &protocol::shutdown_line());
    let ack = read_reply(&mut r);
    assert_eq!(ack.status, Status::Ok);
    // drain flag reached the front-end: wait_drain returns
    front.wait_drain();
    assert!(front.drain_requested());
    front.shutdown();
}
