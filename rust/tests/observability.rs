//! Observability integration suite: trace propagation through the
//! loopback TCP front-end (every lifecycle stage lands in the span
//! ring and the stage histograms), the `{"op":"stats"}` wire op
//! end-to-end (JSON stats + Prometheus text in one reply, jobs gauges
//! on a state-dir server), and metrics survival after an engine panic
//! (the poison-tolerance satellite, end to end).
//!
//! Runs without AOT artifacts (synthetic weights / stub engines).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::service::{AnalogEngine, Engine};
use memdiff::coordinator::{
    EngineRegistry, Service, ServiceConfig, SolverChoice, SolverFamily,
    TaskKind,
};
use memdiff::crossbar::NoiseModel;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::schedule::VpSchedule;
use memdiff::jobs::{JobRunner, JobStore, RunnerConfig};
use memdiff::nn::{AnalogScoreNet, ScoreWeights};
use memdiff::serve::protocol::{self, Status};
use memdiff::serve::{FrontEnd, FrontEndConfig};
use memdiff::util::json::Json;
use memdiff::util::rng::Rng;

// ---------------------------------------------------------------- setup

/// Constant-tag engine for the digital lane.
struct TagEngine(f32);

impl Engine for TagEngine {
    fn dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        3
    }
    fn generate(&self, _s: SolverChoice, _oh: &[f32], _g: f32, n: usize,
                _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        Ok(vec![self.0; n * 2])
    }
}

/// Engine that panics on conditional requests — the worker's panic
/// containment turns that into a failed ticket, never a dead service.
struct PanicEngine;

impl Engine for PanicEngine {
    fn dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        3
    }
    fn generate(&self, _s: SolverChoice, onehot: &[f32], _g: f32, n: usize,
                _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        if onehot.iter().any(|&c| c != 0.0) {
            panic!("poisoned request");
        }
        Ok(vec![1.0; n * 2])
    }
}

fn analog_engine() -> Arc<dyn Engine> {
    // real crossbar substrate, so per-bank read counters show up in the
    // exported series
    let w = ScoreWeights::synthetic(2, 8, 3, 77);
    let params = CellParams { read_noise_frac: 0.0, ..CellParams::default() };
    Arc::new(AnalogEngine::new(
        AnalogScoreNet::from_conductances(&w, params, NoiseModel::Ideal),
        VpSchedule::default(),
        30,
    ))
}

fn svc_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 1,
        batcher: BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(1),
            queue_depth: 0,
        },
        seed: 0xF0F0,
        intra_threads: 1,
    }
}

fn routed_front() -> FrontEnd {
    let mut reg = EngineRegistry::new();
    reg.add_backend("analog", analog_engine(), 1).unwrap();
    reg.add_backend("rust", Arc::new(TagEngine(2.0)), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    let s = Service::start_routed(reg, None, svc_cfg());
    FrontEnd::bind(s, "127.0.0.1:0", FrontEndConfig {
        poll: Duration::from_millis(2),
        ..FrontEndConfig::default()
    })
    .unwrap()
}

fn send_line(w: &mut TcpStream, line: &str) {
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
}

fn read_json(r: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    Json::parse(line.trim()).expect("reply parses as JSON")
}

/// One stats-op round trip; asserts the ok envelope and returns
/// (stats object, prometheus text).
fn fetch_stats(w: &mut TcpStream, r: &mut BufReader<TcpStream>)
               -> (Json, String) {
    send_line(w, &protocol::stats_line(42));
    let msg = read_json(r);
    assert_eq!(msg.get("status").and_then(|s| s.as_str()), Some("ok"));
    assert_eq!(msg.get("id").and_then(|v| v.as_f64()), Some(42.0));
    let stats = msg.get("stats").expect("stats object").clone();
    let prom = msg
        .get("prometheus")
        .and_then(|p| p.as_str())
        .expect("prometheus text")
        .to_string();
    (stats, prom)
}

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("memdiff_obsit_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ------------------------------------------- trace propagation over TCP

/// Requests entering over the wire mint a trace at ingress; after they
/// complete, the stats op shows (a) per-stage latency histograms for
/// the backend that served them, (b) per-bank read counters from the
/// analog substrate, and (c) a full per-request timeline whose spans
/// cover the lifecycle in order.
#[test]
fn wire_requests_trace_end_to_end() {
    memdiff::obs::set_enabled(true);
    let front = routed_front();
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // a couple of requests per lane, paced so every reply is ok
    for id in 0..4u64 {
        let solver = if id % 2 == 0 {
            SolverChoice::AnalogOde
        } else {
            SolverChoice::DigitalOde { steps: 8 }
        };
        send_line(&mut w, &protocol::request_line(
            id, TaskKind::Circle, 2, solver, 0.0, false));
        let reply = protocol::read_reply(&mut r).unwrap();
        assert_eq!(reply.status, Status::Ok, "{:?}", reply.error);
    }

    let (stats, prom) = fetch_stats(&mut w, &mut r);

    // (a) stage histograms, per backend, in both renderings
    let stages = stats.get("stages").and_then(|s| s.as_arr()).unwrap();
    for backend in ["analog", "rust"] {
        assert!(
            stages.iter().any(|st| {
                st.get("backend").and_then(|b| b.as_str()) == Some(backend)
                    && st.get("stage").and_then(|s| s.as_str())
                        == Some("engine_solve")
                    && st.get("count").and_then(|c| c.as_f64()).unwrap_or(0.0)
                        >= 1.0
            }),
            "engine_solve stage row for {backend}: {stages:?}"
        );
    }
    assert!(prom.contains("memdiff_stage_latency_seconds_bucket{"));
    assert!(prom.contains("backend=\"analog\""));
    assert!(prom.contains("memdiff_requests_total"));
    assert!(prom.contains("memdiff_lane_queue_depth{backend=\"analog\"}"));

    // (b) the analog lane's crossbars counted their reads
    let banks = stats.get("banks").and_then(|b| b.as_arr()).unwrap();
    assert!(!banks.is_empty(), "analog engine publishes bank reports");
    let reads: f64 = banks
        .iter()
        .filter_map(|b| b.get("reads").and_then(|v| v.as_f64()))
        .sum();
    assert!(reads > 0.0, "nonzero bank reads after analog traffic");
    assert!(prom.contains("memdiff_bank_reads_total{"));

    // (c) at least one complete timeline: every lifecycle stage present
    // (no decoder here, so `decode` is legitimately absent) and span
    // starts never run backwards relative to delivery
    let traces = stats.get("traces").and_then(|t| t.as_arr()).unwrap();
    let complete = traces.iter().find(|t| {
        let spans = t.get("spans").and_then(|s| s.as_arr());
        let Some(spans) = spans else { return false };
        ["accept", "admit", "queue", "batch_form", "engine_solve", "deliver"]
            .iter()
            .all(|want| {
                spans.iter().any(|sp| {
                    sp.get("stage").and_then(|s| s.as_str()) == Some(want)
                })
            })
    });
    let complete = complete.expect("a trace covering the whole lifecycle");
    let spans = complete.get("spans").and_then(|s| s.as_arr()).unwrap();
    let start = |stage: &str| -> f64 {
        spans
            .iter()
            .find(|sp| sp.get("stage").and_then(|s| s.as_str()) == Some(stage))
            .and_then(|sp| sp.get("start_us"))
            .and_then(|v| v.as_f64())
            .unwrap()
    };
    let deliver = start("deliver");
    for stage in ["accept", "admit", "queue", "batch_form", "engine_solve"] {
        assert!(start(stage) <= deliver,
                "{stage} starts before delivery completes");
    }

    // phase timers ran under the analog solve
    let phases = stats.get("phases").and_then(|p| p.as_arr()).unwrap();
    assert!(
        phases.iter().any(|p| {
            p.get("phase").and_then(|s| s.as_str()) == Some("substep")
                && p.get("count").and_then(|c| c.as_f64()).unwrap_or(0.0) > 0.0
        }),
        "substep phase counted: {phases:?}"
    );

    front.shutdown();
}

// --------------------------------------------- stats op on a jobs server

/// On a `--state-dir` server the stats reply carries the jobs gauges,
/// refreshed in-band, and they survive the job reaching `done`.
#[test]
fn stats_op_reports_jobs_gauges() {
    let dir = tmp("gauges");
    let mut reg = EngineRegistry::new();
    reg.add_backend("rust", Arc::new(TagEngine(3.0)), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "rust").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    let service = Arc::new(Service::start_routed(reg, None, svc_cfg()));
    let store = Arc::new(JobStore::open(&dir).unwrap());
    let runner = JobRunner::start(Arc::clone(&service), store,
                                  RunnerConfig::default());
    let front = FrontEnd::bind_shared(service, Some(runner), "127.0.0.1:0",
                                      FrontEndConfig {
                                          poll: Duration::from_millis(2),
                                          ..FrontEndConfig::default()
                                      })
    .unwrap();
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // enqueue one durable job and long-poll it to `done`
    send_line(&mut w, &protocol::enqueue_line(
        1, TaskKind::Circle, 2, SolverChoice::DigitalOde { steps: 4 },
        0.0, false, 0, None, None));
    let ack = protocol::read_reply(&mut r).unwrap();
    assert_eq!(ack.status, Status::Ok, "{:?}", ack.error);
    let job = ack.job.expect("durable ack carries the job id");
    send_line(&mut w, &protocol::result_line(2, job, 10_000));
    let done = protocol::read_reply(&mut r).unwrap();
    assert_eq!(done.status, Status::Ok, "{:?}", done.error);
    assert_eq!(done.state.as_deref(), Some("done"));

    let (stats, prom) = fetch_stats(&mut w, &mut r);
    let jobs = stats.get("jobs").expect("state-dir server exports jobs");
    assert!(jobs.get("enqueued_total").and_then(|v| v.as_f64()).unwrap()
                >= 1.0);
    assert!(jobs.get("done").and_then(|v| v.as_f64()).unwrap() >= 1.0);
    assert!(prom.contains("memdiff_jobs{state=\"done\"}"));
    assert!(prom.contains("memdiff_jobs_enqueued_total"));

    front.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------- SLO breach -> flight record, over the wire

/// Engine slow enough to blow a 1 ms latency objective on every request.
struct SlowEngine;

impl Engine for SlowEngine {
    fn dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        3
    }
    fn generate(&self, _s: SolverChoice, _oh: &[f32], _g: f32, n: usize,
                _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(Duration::from_millis(25));
        Ok(vec![9.0; n * 2])
    }
}

/// The SLO acceptance path end to end: a deployment with a 1 ms digital
/// objective under deliberately slow load latches `slo:<backend>:<class>`
/// through the health monitor, the latch auto-writes a flight record,
/// `{"op":"dump"}` returns a dump naming the breaching class with its
/// p99 exemplar trace, and once the load stops the alert clears back
/// through the hysteresis band.
#[test]
fn slo_breach_latches_dumps_and_clears_over_the_wire() {
    use memdiff::obs::{FlightRecorder, HealthConfig, HealthMonitor, SloConfig};
    memdiff::obs::set_enabled(true);
    let dir = tmp("slo_e2e");

    // distinct backend name so the latency series (and the rule) can't
    // be touched by the other tests in this binary
    let mut reg = EngineRegistry::new();
    reg.add_backend("analog", Arc::new(TagEngine(1.0)), 1).unwrap();
    reg.add_backend("slowrust", Arc::new(SlowEngine), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "slowrust").unwrap();
    let service = Arc::new(Service::start_routed(reg, None, svc_cfg()));

    let rec = Arc::new(FlightRecorder::with_limits(
        &dir, Arc::clone(&service.metrics), "slo-e2e".into(), 8,
        Duration::ZERO).unwrap());
    // 1 ms digital objective, windows tight enough to latch and clear
    // inside the test; analog classes keep the generous default
    let mut p99_ms = [30_000.0; 4];
    p99_ms[2] = 1.0;
    p99_ms[3] = 1.0;
    let slo_cfg = SloConfig {
        p99_ms,
        target_frac: 0.9,
        fast_window_ms: 300,
        slow_window_ms: 900,
        burn_threshold: 1.0,
        clear_frac: 0.5,
        streak: 1,
        ..SloConfig::default()
    };
    // probes on demand only: the monitor tick must evaluate just the
    // SLO rules here (stub engines would fail a KL probe)
    let mon = HealthMonitor::new_full(
        HealthConfig { probe_interval_ms: 0, ..HealthConfig::default() },
        slo_cfg,
        Arc::clone(service.registry()),
        Arc::clone(&service.mode_gate),
        Some(Arc::clone(&rec)));
    rec.attach_health(&mon);
    // no mon.start(): ticking manually keeps the timing deterministic
    let front = FrontEnd::bind_deployment(
        service, None, Some(Arc::clone(&mon)), Some(Arc::clone(&rec)),
        "127.0.0.1:0",
        FrontEndConfig { poll: Duration::from_millis(2),
                         ..FrontEndConfig::default() })
        .unwrap();
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // paced slow load: every digital request blows the 1 ms budget
    for id in 0..8u64 {
        send_line(&mut w, &protocol::request_line(
            id, TaskKind::Circle, 1, SolverChoice::DigitalOde { steps: 4 },
            0.0, false));
        let reply = protocol::read_reply(&mut r).unwrap();
        assert_eq!(reply.status, Status::Ok, "{:?}", reply.error);
    }

    let rule = "slo:slowrust:digital_uncond";
    mon.tick();
    assert!(!mon.healthy(), "sustained breach latches: {:?}", mon.firing());
    assert!(mon.firing().iter().any(|f| f == rule), "{:?}", mon.firing());

    // the latch auto-wrote a flight record naming the rule
    let auto = rec.dumps();
    assert!(
        auto.iter().any(|p| p
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.contains("alert-slo_slowrust_digital_uncond"))),
        "alert latch writes a flight record: {auto:?}"
    );

    // the wire dump op returns the black box: breaching rule in the
    // firing list, breaching class in the SLO report, and the class's
    // p99 exemplar trace in the embedded stats
    send_line(&mut w, &protocol::dump_line(7));
    let msg = read_json(&mut r);
    assert_eq!(msg.get("status").and_then(|s| s.as_str()), Some("ok"),
               "{msg:?}");
    let path = msg.get("path").and_then(|p| p.as_str()).expect("dump path");
    assert!(path.ends_with(".json"), "{path}");
    let dump = msg.get("dump").expect("dump body in the reply");
    let firing = dump.get("firing").and_then(|f| f.as_arr()).unwrap();
    assert!(firing.iter().any(|f| f.as_str() == Some(rule)), "{firing:?}");
    let slo = dump
        .get("health")
        .and_then(|h| h.get("slo"))
        .and_then(|s| s.as_arr())
        .expect("health report carries the slo block");
    let breached = slo
        .iter()
        .find(|s| s.get("rule").and_then(|r| r.as_str()) == Some(rule))
        .expect("breaching class in the slo report");
    assert_eq!(breached.get("firing"), Some(&Json::Bool(true)));
    let lat = dump
        .get("stats")
        .and_then(|s| s.get("class_latency"))
        .and_then(|l| l.as_arr())
        .expect("stats carry class latency rows");
    let row = lat
        .iter()
        .find(|l| {
            l.get("backend").and_then(|b| b.as_str()) == Some("slowrust")
                && l.get("class").and_then(|c| c.as_str())
                    == Some("digital_uncond")
        })
        .expect("breaching class has a latency row");
    assert!(
        row.get("p99_exemplar_trace").and_then(|t| t.as_f64()).unwrap_or(0.0)
            > 0.0,
        "the p99 is attributable to a concrete trace: {row:?}"
    );

    // load stops; once both windows roll past the breach the burn
    // decays and the latch clears through the hysteresis band
    std::thread::sleep(Duration::from_millis(1000));
    mon.tick();
    std::thread::sleep(Duration::from_millis(30));
    mon.tick();
    assert!(mon.healthy(), "alert clears after the windows roll: {:?}",
            mon.firing());

    front.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------ metrics survive an engine panic

/// The poison satellite end to end: a panicking engine fails its own
/// ticket, and the observability path — snapshot, JSON, Prometheus —
/// keeps answering afterwards with the panic counted.
#[test]
fn stats_survive_an_engine_panic() {
    let reg = EngineRegistry::single(Arc::new(PanicEngine));
    let s = Service::start_routed(reg, None, svc_cfg());
    // conditional request trips the panic; its ticket fails
    let poisoned = s
        .submit_nb(memdiff::coordinator::GenRequest {
            id: 0,
            task: TaskKind::Letter(1),
            n_samples: 1,
            solver: SolverChoice::AnalogOde,
            guidance: 0.0,
            decode: false,
            trace: memdiff::obs::TraceId::mint(),
        })
        .unwrap();
    assert!(poisoned.recv().is_err(), "poisoned ticket fails");
    // the service keeps serving and the exporters keep rendering
    let ok = s
        .generate(TaskKind::Circle, 1, SolverChoice::AnalogOde, 0.0, false)
        .unwrap();
    assert_eq!(ok.samples, vec![1.0; 2]);
    let snap = s.metrics.snapshot();
    assert!(snap.worker_panics >= 1, "panic counted");
    let prom = memdiff::obs::export::render_prometheus(&snap);
    assert!(prom.contains("memdiff_worker_panics_total"));
    let json = memdiff::obs::export::stats_json(&snap).to_string();
    let parsed = Json::parse(&json).unwrap();
    assert!(parsed.get("worker_panics").and_then(|v| v.as_f64()).unwrap()
                >= 1.0);
    s.shutdown();
}
