//! Health-monitor loopback e2e: the full alert lifecycle driven over a
//! live TCP server — a healthy report, drift injected through the wire
//! `age` maintenance verb, the drift alert firing in the `health` reply,
//! a wire `reprogram` clearing it — plus the serving-metrics exclusion
//! proof for self-test probes and the monitor-less error contract.
//!
//! Runs without AOT artifacts (synthetic weights).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::service::{AnalogEngine, Engine, RustDigitalEngine};
use memdiff::coordinator::{
    EngineRegistry, Service, ServiceConfig, SolverChoice, SolverFamily,
    TaskKind,
};
use memdiff::crossbar::NoiseModel;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::schedule::VpSchedule;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::obs::{HealthConfig, HealthMonitor};
use memdiff::serve::protocol::{self, HealthAction, Status};
use memdiff::serve::{FrontEnd, FrontEndConfig};
use memdiff::util::json::Json;

fn weights() -> ScoreWeights {
    ScoreWeights::synthetic(2, 8, 3, 77)
}

fn analog_engine() -> Arc<dyn Engine> {
    let params = CellParams { read_noise_frac: 0.0, ..CellParams::default() };
    Arc::new(AnalogEngine::new(
        AnalogScoreNet::from_conductances(&weights(), params,
                                          NoiseModel::Ideal),
        VpSchedule::default(),
        30,
    ))
}

fn rust_engine() -> Arc<dyn Engine> {
    Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(weights()),
        sched: VpSchedule::default(),
    })
}

fn svc_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 1,
        batcher: BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(1),
            queue_depth: 0,
        },
        seed: 0xF0F0,
        intra_threads: 1,
    }
}

fn routed_service() -> Arc<Service> {
    let mut reg = EngineRegistry::new();
    reg.add_backend("analog", analog_engine(), 1).unwrap();
    reg.add_backend("rust", rust_engine(), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    Arc::new(Service::start_routed(reg, None, svc_cfg()))
}

/// A monitor over the service's registry, probes on demand only, the
/// background thread NOT started — the wire handler ticks it, so the
/// test is deterministic.
fn monitor_for(service: &Arc<Service>, cfg: HealthConfig)
               -> Arc<HealthMonitor> {
    HealthMonitor::new(cfg, Arc::clone(service.registry()),
                       Arc::clone(&service.mode_gate))
}

fn send(w: &mut TcpStream, line: &str) {
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
}

/// Read one raw reply line as JSON (health replies carry more than the
/// typed [`protocol::WireReply`] surfaces).
fn recv_json(r: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    Json::parse(line.trim()).expect("reply line parses")
}

fn health_of(reply: &Json) -> &Json {
    assert_eq!(reply.get("status").and_then(|s| s.as_str()), Some("ok"),
               "health op ok: {reply:?}");
    reply.get("health").expect("health payload")
}

fn healthy_bit(reply: &Json) -> bool {
    health_of(reply).get("healthy") == Some(&Json::Bool(true))
}

fn firing_names(reply: &Json) -> Vec<String> {
    health_of(reply)
        .get("alerts").and_then(|a| a.as_arr()).unwrap_or(&[])
        .iter()
        .filter(|a| a.get("firing") == Some(&Json::Bool(true)))
        .filter_map(|a| a.get("name").and_then(|n| n.as_str()))
        .map(String::from)
        .collect()
}

/// The tentpole's acceptance path, over the wire: healthy → `age`
/// injects a year-scale retention loss and the drift alert fires in the
/// reply (what `memdiff client --health` prints and what flips /healthz
/// to 503) → the server keeps serving while unhealthy → `reprogram`
/// write-verifies the array and the alert clears.
#[test]
fn wire_health_lifecycle_drift_fires_and_reprogram_clears() {
    let service = routed_service();
    let mon = monitor_for(&service, HealthConfig {
        probe_interval_ms: 0,
        ..HealthConfig::default()
    });
    let front = FrontEnd::bind_full(
        Arc::clone(&service), None, Some(Arc::clone(&mon)), "127.0.0.1:0",
        FrontEndConfig { poll: Duration::from_millis(2),
                         ..FrontEndConfig::default() })
        .unwrap();
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // freshly programmed: healthy, nothing firing
    send(&mut w, &protocol::health_line(1, HealthAction::Status));
    let reply = recv_json(&mut r);
    assert!(healthy_bit(&reply), "fresh array is healthy: {reply:?}");
    assert!(firing_names(&reply).is_empty());

    // inject drift: dt = 1e12 s pushes mean |dG| far past the default
    // 4e-4 mS threshold, so the drift alert latches on the handler's tick
    send(&mut w, &protocol::health_line(
        2, HealthAction::Age { dt_s: 1e12 }));
    let reply = recv_json(&mut r);
    assert!(!healthy_bit(&reply), "aged array must alert: {reply:?}");
    assert!(firing_names(&reply).iter().any(|n| n == "drift:analog"),
            "drift:analog fires, got {:?}", firing_names(&reply));
    // the drift report backs the alert with numbers
    let drift = health_of(&reply).get("drift").and_then(|d| d.as_arr())
        .expect("drift report");
    let analog = drift.iter()
        .find(|b| b.get("backend").and_then(|n| n.as_str()) == Some("analog"))
        .expect("analog backend drift");
    assert!(analog.get("mean_abs_ms").and_then(|v| v.as_f64()).unwrap()
            > 4.0e-4);

    // an unhealthy device still serves (alerting is advisory; routing
    // away is the operator's call)
    send(&mut w, &protocol::request_line(
        3, TaskKind::Circle, 2, SolverChoice::AnalogOde, 0.0, false));
    let gen = protocol::read_reply(&mut r).unwrap();
    assert_eq!((gen.id, gen.status), (3, Status::Ok), "{:?}", gen.error);
    assert_eq!(gen.samples.len(), 4);

    // reprogram: write-verify re-baselines the array, drift drops to 0,
    // the alert clears through hysteresis in the same reply
    send(&mut w, &protocol::health_line(4, HealthAction::Reprogram));
    let reply = recv_json(&mut r);
    assert!(healthy_bit(&reply), "reprogram heals: {reply:?}");
    assert!(firing_names(&reply).is_empty());
    let reprog = health_of(&reply).get("reprogram").and_then(|v| v.as_arr())
        .expect("reprogram records");
    assert!(reprog.iter().any(
        |p| p.get("backend").and_then(|n| n.as_str()) == Some("analog")));
    assert!(health_of(&reply).get("reprograms").and_then(|v| v.as_f64())
            .unwrap() >= 1.0);

    // malformed maintenance verbs answer error without killing the conn
    send(&mut w, r#"{"op":"health","id":5,"action":"warp"}"#);
    let bad = recv_json(&mut r);
    assert_eq!(bad.get("status").and_then(|s| s.as_str()), Some("error"));
    send(&mut w, &protocol::health_line(6, HealthAction::Status));
    assert!(healthy_bit(&recv_json(&mut r)));

    front.shutdown();
}

/// Self-test probes ride `Engine::generate` directly, underneath the
/// batcher — so a probe sweep moves the probe counters but provably
/// never the serving counters the SLO dashboards watch.
#[test]
fn probes_stay_out_of_serving_metrics_on_a_live_server() {
    let service = routed_service();
    let mon = monitor_for(&service, HealthConfig {
        probe_interval_ms: 0,
        probe_samples: 64,
        probe_steps: 20,
        // scoring 64 samples is noisy by design: open budgets keep this
        // exclusion test independent of the quality gates
        kl_budget: [100.0; 4],
        ..HealthConfig::default()
    });
    let front = FrontEnd::bind_full(
        Arc::clone(&service), None, Some(Arc::clone(&mon)), "127.0.0.1:0",
        FrontEndConfig { poll: Duration::from_millis(2),
                         ..FrontEndConfig::default() })
        .unwrap();
    let metrics = front.metrics();
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // one real request: the serving counters move
    send(&mut w, &protocol::request_line(
        1, TaskKind::Circle, 3, SolverChoice::AnalogOde, 0.0, false));
    assert_eq!(protocol::read_reply(&mut r).unwrap().status, Status::Ok);
    let before = metrics.snapshot();
    assert_eq!((before.requests, before.samples), (1, 3));

    // a full probe sweep (every backend, every routed class)
    mon.probe_now();
    send(&mut w, &protocol::health_line(2, HealthAction::Status));
    let reply = recv_json(&mut r);
    let probes = health_of(&reply).get("probes").and_then(|p| p.as_arr())
        .expect("probe results");
    assert!(!probes.is_empty(), "probes ran");
    assert!(probes.iter().all(
        |p| p.get("ok") == Some(&Json::Bool(true))), "{probes:?}");

    // ...and the serving counters did not move
    let after = metrics.snapshot();
    assert_eq!((after.requests, after.samples), (1, 3),
               "probe traffic must not count as served load");

    front.shutdown();
}

/// A server without the monitor answers every health op with a typed
/// error (and keeps serving) — the same contract job ops have without
/// `--state-dir`.
#[test]
fn health_op_without_monitor_is_a_typed_error() {
    let service = routed_service();
    let front = FrontEnd::bind_full(
        Arc::clone(&service), None, None, "127.0.0.1:0",
        FrontEndConfig::default())
        .unwrap();
    let stream = TcpStream::connect(front.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    send(&mut w, &protocol::health_line(1, HealthAction::Status));
    let reply = recv_json(&mut r);
    assert_eq!(reply.get("status").and_then(|s| s.as_str()), Some("error"));
    assert!(reply.get("error").and_then(|e| e.as_str()).unwrap()
            .contains("no health monitor"));

    send(&mut w, &protocol::request_line(
        2, TaskKind::Circle, 1, SolverChoice::AnalogOde, 0.0, false));
    assert_eq!(protocol::read_reply(&mut r).unwrap().status, Status::Ok);
    front.shutdown();
}
