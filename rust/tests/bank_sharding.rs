//! Banked-vs-monolithic parity suite for the macro-bank sharding
//! subsystem (`crossbar::bank`).
//!
//! The monolithic `CrossbarLayer` is the oracle: deployed from the same
//! conductances with a uniform gain, the banked layer must be **bitwise**
//! equal under `Ideal` evaluation — for every tile-grid shape including
//! ragged edges, in both the scalar and batched lanes, and end-to-end
//! through a score net wider than one macro driven by both solvers.
//! Where device noise enters (`ReadFast` with per-bank streams) the parity
//! is statistical (matching first two moments).
//!
//! Runs on synthetic weights so it needs no built artifacts.

use std::sync::Arc;

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::service::AnalogEngine;
use memdiff::coordinator::{Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::crossbar::mapper::map_layer;
use memdiff::crossbar::{BankedCrossbarLayer, Banking, CrossbarLayer, NoiseModel};
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::diffusion::schedule::VpSchedule;
use memdiff::energy::model::AnalogCost;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::util::rng::Rng;
use memdiff::util::stats;
use memdiff::util::tensor::Mat;

fn quiet() -> CellParams {
    CellParams { read_noise_frac: 0.0, ..CellParams::default() }
}

fn test_weights(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| 0.6 * rng.gaussian_f32())
}

/// Grid shapes spanning 1×1, 1×N, M×1 and M×N — all with ragged edges.
const GRID_SHAPES: [(usize, usize); 5] =
    [(20, 20), (16, 70), (70, 16), (40, 70), (64, 96)];

#[test]
fn banked_bitwise_matches_monolithic_ideal_all_grids() {
    for (rows, cols) in GRID_SHAPES {
        let w = test_weights(rows, cols, 100 + rows as u64);
        let m = map_layer(&w);
        let mono = CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet());
        let banked = BankedCrossbarLayer::from_conductances(
            &m.g_target, m.gain, quiet(), 7,
        );
        assert_eq!(banked.grid(),
                   (rows.div_ceil(32), cols.div_ceil(32)), "{rows}x{cols}");

        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..rows).map(|i| (i as f32 * 0.29).sin()).collect();
        let mut a = vec![0.0f32; cols];
        let mut b = vec![0.0f32; cols];
        mono.forward(&v, &mut a, NoiseModel::Ideal, &mut rng);
        banked.forward(&v, &mut b, NoiseModel::Ideal, &mut rng);
        assert_eq!(a, b, "{rows}x{cols} scalar lane");

        for batch in [1usize, 5, 8] {
            let vb: Vec<f32> = (0..batch * rows)
                .map(|i| (i as f32 * 0.17).cos() - 0.3)
                .collect();
            let mut ab = vec![0.0f32; batch * cols];
            let mut bb = vec![0.0f32; batch * cols];
            mono.forward_batch(&vb, &mut ab, batch, NoiseModel::Ideal, &mut rng);
            banked.forward_batch(&vb, &mut bb, batch, NoiseModel::Ideal,
                                 &mut rng);
            assert_eq!(ab, bb, "{rows}x{cols} batched lane B={batch}");
        }
    }
}

#[test]
fn banked_read_fast_statistical_parity() {
    let (rows, cols) = (48usize, 48usize);
    let w = test_weights(rows, cols, 200);
    let m = map_layer(&w);
    let params = CellParams::default(); // 1% read noise
    let mono =
        CrossbarLayer::from_conductances(&m.g_target, m.gain, params.clone());
    let banked =
        BankedCrossbarLayer::from_conductances(&m.g_target, m.gain, params, 9);
    let v: Vec<f32> = (0..rows).map(|i| 0.25 + 0.01 * (i % 7) as f32).collect();

    let n = 3000;
    let mut rng = Rng::new(2);
    let mut out = vec![0.0f32; cols];
    // column 0 (tile-column 0) and column 40 (ragged-adjacent tile-column 1)
    let (mut m0, mut m40, mut b0, mut b40) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    for _ in 0..n {
        mono.forward(&v, &mut out, NoiseModel::ReadFast, &mut rng);
        m0.push(out[0]);
        m40.push(out[40]);
        banked.forward(&v, &mut out, NoiseModel::ReadFast, &mut rng);
        b0.push(out[0]);
        b40.push(out[40]);
    }
    for (mc, bc, label) in [(&m0, &b0, "col0"), (&m40, &b40, "col40")] {
        let (mm, ms) = (stats::mean(mc), stats::std(mc));
        let (bm, bs) = (stats::mean(bc), stats::std(bc));
        assert!((mm - bm).abs() < 0.02 * mm.abs().max(0.1),
                "{label} means {mm} vs {bm}");
        assert!((ms - bs).abs() / ms.max(1e-9) < 0.15,
                "{label} stds {ms} vs {bs}");
        assert!(ms > 0.0);
    }
}

#[test]
fn wide_net_digital_and_analog_solvers_end_to_end() {
    // a score net with hidden = 48 > one macro: both solvers, both lanes
    let w = ScoreWeights::synthetic(2, 48, 3, 300);

    // digital reference runs the wide net out of the box
    let dig = DigitalScoreNet::new(w.clone());
    let sampler = DigitalSampler::new(&dig, SamplerMode::Ode);
    let mut rng = Rng::new(3);
    let (scalar, _) = sampler.sample_batch(6, &[0.0, 0.0, 0.0], 12, &mut rng);
    let mut rng = Rng::new(3);
    let (batched, _) = sampler.sample_batched(6, &[0.0, 0.0, 0.0], 12, &mut rng);
    assert_eq!(scalar, batched, "digital wide net batched lane");
    assert!(scalar.iter().all(|v| v.is_finite()));

    // analog: auto-banked net must match the forced-monolithic oracle
    // bitwise through the full closed-loop ODE solve, in both lanes
    let banked = AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
    assert!(banked.is_banked(), "hidden 48 must shard");
    let mono = AnalogScoreNet::from_conductances_with(
        &w, quiet(), NoiseModel::Ideal, Banking::ForceMonolithic);
    let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(150);

    let mut rng = Rng::new(4);
    let s_banked =
        AnalogSolver::new(&banked, cfg.clone()).solve_batch(4, &[0.0, 0.0, 0.0],
                                                            &mut rng);
    let mut rng = Rng::new(4);
    let s_mono =
        AnalogSolver::new(&mono, cfg.clone()).solve_batch(4, &[0.0, 0.0, 0.0],
                                                          &mut rng);
    assert_eq!(s_banked, s_mono, "scalar lane banked vs oracle");

    let mut rng = Rng::new(4);
    let b_banked =
        AnalogSolver::new(&banked, cfg.clone()).solve_batched(4, &[0.0, 0.0, 0.0],
                                                              &mut rng);
    assert_eq!(s_banked, b_banked, "batched lane vs scalar lane");
}

#[test]
fn wide_net_programs_with_per_bank_stats() {
    let w = ScoreWeights::synthetic(2, 48, 3, 400);
    let mut rng = Rng::new(5);
    let (net, pulses) = AnalogScoreNet::program_from_weights(
        &w, quiet(), 0.0005, NoiseModel::Ideal, &mut rng);
    assert!(pulses > 0);
    assert!(net.is_banked());
    let reports = net.bank_report();
    assert_eq!(reports.len(), 3);
    assert_eq!(reports[1].n_banks(), 4, "48x48 layer is a 2x2 grid");
    for b in &reports[1].banks {
        assert!(b.mean_pulses > 0.0, "write-verify must pulse per bank");
        assert!(b.gain > 0.0);
    }
    // per-tile-column gains may differ; deployment must stay close to the
    // requested weights at each block's own scale
    let (e1, e2, _e3) = net.effective_weights();
    assert!(e1.max_abs_diff(&w.w1) < 0.1, "{}", e1.max_abs_diff(&w.w1));
    assert!(e2.max_abs_diff(&w.w2) < 0.1, "{}", e2.max_abs_diff(&w.w2));
}

#[test]
fn service_surfaces_bank_topology_and_reads() {
    let w = ScoreWeights::synthetic(2, 48, 3, 500);
    let net = AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
    let engine = Arc::new(AnalogEngine::new(net, VpSchedule::default(), 40));
    let svc = Service::start(
        engine,
        None,
        ServiceConfig {
            workers: 1,
            batcher: BatcherConfig {
                max_batch_samples: 16,
                linger: std::time::Duration::from_millis(1),
                ..BatcherConfig::default()
            },
            seed: 6,
            intra_threads: 0,
        },
    );
    // topology is visible before any traffic (reads = 0)
    let before = svc.metrics.snapshot();
    assert_eq!(before.banking.len(), 3);
    assert_eq!(before.banking[1].n_banks(), 4);
    assert_eq!(before.banking[1].total_reads(), 0);

    let r = svc
        .generate(TaskKind::Circle, 3, SolverChoice::AnalogOde, 0.0, false)
        .unwrap();
    assert_eq!(r.samples.len(), 6);
    // the modeled energy charges the *actual* bank topology (8 macros,
    // 98 TIAs, fanout buffers), so it must exceed what the paper-shape
    // default would report for the same 3 samples
    assert!(
        r.hw_energy_j > 3.0 * AnalogCost::unconditional_projected().energy_j(),
        "banked topology must charge more energy: {}",
        r.hw_energy_j
    );

    let after = svc.metrics.snapshot();
    assert!(after.banking[1].total_reads() > 0,
            "per-bank read counters must advance with traffic");
    let report = after.report();
    assert!(report.contains("banks=L0:"), "{report}");
    svc.shutdown();
}
