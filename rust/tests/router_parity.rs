//! Router parity: every request class served through the multi-backend
//! `Service` must return **bitwise-identical** samples to a direct
//! single-engine `Service` given the same seed/config — in Ideal and
//! noisy modes — and the Hlo→rust fallback chain must degrade (not fail)
//! under the default stub runtime.
//!
//! Uses the synthetic weight fixture, so the suite runs without AOT
//! artifacts.  Determinism relies on two contracts: engine construction
//! is deterministic (fixed bank-stream seeds), and a backend's worker RNG
//! seed depends only on the backend-local worker index, so a one-worker
//! lane replays the exact RNG sequence of a one-worker single-engine
//! service.

use std::sync::Arc;

use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::deploy::{self, BackendKind, DeployPlan, EngineRegistry};
use memdiff::coordinator::service::{AnalogEngine, Engine, HloEngine, RustDigitalEngine};
use memdiff::coordinator::{
    GenRequest, GenResponse, Service, ServiceConfig, SolverChoice, SolverFamily,
    TaskKind,
};
use memdiff::crossbar::NoiseModel;
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::schedule::VpSchedule;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;

const SEED: u64 = 0xBAD5_EED5;
const SUBSTEPS: usize = 40;

fn weights() -> ScoreWeights {
    ScoreWeights::synthetic(2, 8, 3, 77)
}

fn sched() -> VpSchedule {
    VpSchedule::default()
}

fn analog_engine(noise: NoiseModel) -> Arc<dyn Engine> {
    let params = if matches!(noise, NoiseModel::Ideal) {
        CellParams { read_noise_frac: 0.0, ..CellParams::default() }
    } else {
        CellParams::default()
    };
    Arc::new(AnalogEngine::new(
        AnalogScoreNet::from_conductances(&weights(), params, noise),
        sched(),
        SUBSTEPS,
    ))
}

fn rust_engine() -> Arc<dyn Engine> {
    Arc::new(RustDigitalEngine { net: DigitalScoreNet::new(weights()), sched: sched() })
}

fn svc_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 1,
        batcher: BatcherConfig {
            max_batch_samples: 64,
            linger: std::time::Duration::from_millis(1),
            ..BatcherConfig::default()
        },
        seed: SEED,
        intra_threads: 0,
    }
}

/// The two-backend deployment under test: one-worker lanes so request
/// streams replay deterministically.
fn routed_service(noise: NoiseModel) -> Service {
    let mut reg = EngineRegistry::new();
    reg.add_backend("analog", analog_engine(noise), 1).unwrap();
    reg.add_backend("rust", rust_engine(), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    Service::start_routed(reg, None, svc_cfg())
}

/// One request per class, cycled `reps` times — the full class cross.
fn scenario(reps: usize) -> Vec<(TaskKind, SolverChoice, usize)> {
    let mut out = Vec::new();
    for r in 0..reps {
        out.push((TaskKind::Circle, SolverChoice::AnalogOde, 3 + r));
        out.push((TaskKind::Letter(r % 3), SolverChoice::AnalogSde, 2 + r));
        out.push((TaskKind::Circle, SolverChoice::DigitalOde { steps: 12 }, 4 + r));
        out.push((TaskKind::Letter((r + 1) % 3),
                  SolverChoice::DigitalSde { steps: 12 }, 3 + r));
    }
    out
}

/// Run the scenario through a service sequentially (one blocking request
/// at a time, so batches and RNG consumption replay exactly), keeping
/// only requests `filter` accepts.
fn run_filtered(svc: &Service, reqs: &[(TaskKind, SolverChoice, usize)],
                filter: impl Fn(&SolverChoice) -> bool) -> Vec<GenResponse> {
    reqs.iter()
        .filter(|(_, s, _)| filter(s))
        .map(|&(task, solver, n)| {
            svc.generate(task, n, solver, 2.0, false).unwrap()
        })
        .collect()
}

fn assert_bitwise(a: &[GenResponse], b: &[GenResponse], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: response counts");
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.samples.len(), rb.samples.len(), "{what} req {i}");
        for (k, (x, y)) in ra.samples.iter().zip(&rb.samples).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{what} req {i} sample {k}: {x} vs {y}");
        }
    }
}

fn parity_for(noise: NoiseModel, what: &str) {
    let reqs = scenario(3);

    // the routed service sees the full interleaved mixed-class stream
    let routed = routed_service(noise);
    let via_router = run_filtered(&routed, &reqs, |_| true);
    let snap = routed.metrics.snapshot();
    routed.shutdown();

    // each single-engine service replays only its family's subsequence
    let analog_only = Service::start(analog_engine(noise), None, svc_cfg());
    let via_analog = run_filtered(&analog_only, &reqs, |s| s.is_analog());
    analog_only.shutdown();

    let rust_only = Service::start(rust_engine(), None, svc_cfg());
    let via_rust = run_filtered(&rust_only, &reqs, |s| !s.is_analog());
    rust_only.shutdown();

    let routed_analog: Vec<GenResponse> = reqs
        .iter()
        .zip(&via_router)
        .filter(|((_, s, _), _)| s.is_analog())
        .map(|(_, r)| r.clone())
        .collect();
    let routed_rust: Vec<GenResponse> = reqs
        .iter()
        .zip(&via_router)
        .filter(|((_, s, _), _)| !s.is_analog())
        .map(|(_, r)| r.clone())
        .collect();

    assert_bitwise(&routed_analog, &via_analog, &format!("{what}/analog"));
    assert_bitwise(&routed_rust, &via_rust, &format!("{what}/digital"));

    // per-backend gauges saw exactly the class split
    assert_eq!(snap.backends.len(), 2);
    let total_analog: usize = reqs
        .iter()
        .filter(|(_, s, _)| s.is_analog())
        .map(|&(_, _, n)| n)
        .sum();
    let total_rust: usize = reqs
        .iter()
        .filter(|(_, s, _)| !s.is_analog())
        .map(|&(_, _, n)| n)
        .sum();
    assert_eq!(snap.backends[0].samples as usize, total_analog, "{what}");
    assert_eq!(snap.backends[1].samples as usize, total_rust, "{what}");
    assert!(snap.backends[0].hw_energy_j > 0.0,
            "{what}: analog energy accounted");
    assert!(snap.degraded.is_empty(), "{what}: nothing degraded");
}

#[test]
fn routed_bitwise_identical_to_single_engine_ideal() {
    parity_for(NoiseModel::Ideal, "ideal");
}

#[test]
fn routed_bitwise_identical_to_single_engine_noisy() {
    parity_for(NoiseModel::ReadFast, "readfast");
}

#[test]
fn hlo_fallback_serves_digital_through_rust() {
    let mut plan = DeployPlan::default();
    plan.apply_overrides("digital=hlo,analog_workers=1,rust_workers=1,hlo_workers=1")
        .unwrap();
    let mut factory = |kind: BackendKind, _weights: Option<&str>|
     -> anyhow::Result<Arc<dyn Engine>> {
        Ok(match kind {
            BackendKind::Analog => analog_engine(NoiseModel::Ideal),
            BackendKind::Rust => rust_engine(),
            BackendKind::Hlo => {
                let store = ArtifactStore::open_default()?;
                let n_classes = store.meta().n_classes;
                Arc::new(HloEngine { store, n_classes })
            }
        })
    };
    let svc = deploy::start_deployed(&plan, &mut factory, None, svc_cfg())
        .expect("fallback chain must not fail startup");

    let reqs = scenario(2);
    let digital = run_filtered(&svc, &reqs, |s| !s.is_analog());
    let snap = svc.metrics.snapshot();
    svc.shutdown();

    if snap.degraded.is_empty() {
        // a real vendored PJRT runtime with artifacts answered: nothing
        // further to assert about the fallback path on this build
        eprintln!("hlo runtime available; fallback not exercised");
        return;
    }
    // the stub runtime (the default build) must have degraded BOTH
    // digital classes to rust and recorded it
    assert!(!cfg!(pjrt_vendored),
            "vendored runtime should not degrade unless artifacts are absent");
    assert_eq!(snap.degraded.len(), 2, "{:?}", snap.degraded);
    for d in &snap.degraded {
        assert!(d.contains("hlo->rust"), "{d}");
    }
    assert!(snap.report().contains("degraded="), "{}", snap.report());
    let rust_names: Vec<&str> =
        snap.backends.iter().map(|b| b.name.as_str()).collect();
    assert!(rust_names.contains(&"rust"), "{rust_names:?}");
    assert!(!rust_names.contains(&"hlo"), "failed backend not registered");

    // and the degraded path is *exactly* the rust path, bitwise
    let rust_only = Service::start(rust_engine(), None, svc_cfg());
    let direct = run_filtered(&rust_only, &reqs, |s| !s.is_analog());
    rust_only.shutdown();
    assert_bitwise(&digital, &direct, "fallback/digital");
}

#[test]
fn mixed_class_shutdown_drains_all_lanes_end_to_end() {
    // queue mixed-family work on real engines and shut down immediately:
    // the per-lane drain + no-dropped-request invariant must answer every
    // request across both lanes
    let svc = routed_service(NoiseModel::Ideal);
    let mut rxs = Vec::new();
    for (task, solver, n) in scenario(2) {
        rxs.push(svc
            .submit(GenRequest {
                id: 0,
                task,
                n_samples: n,
                solver,
                guidance: 2.0,
                decode: false,
                trace: memdiff::obs::TraceId::NONE,
            })
            .unwrap());
    }
    let expected = rxs.len();
    svc.shutdown();
    let mut answered = 0;
    for rx in rxs {
        let resp = rx.recv();
        assert!(resp.is_ok(), "delivered before worker join: {:?}", resp.err());
        answered += 1;
    }
    assert_eq!(answered, expected, "no request dropped on any lane");
}

/// The ROADMAP's per-class quality gate: on the healthy two-backend
/// deployment, every routed class's self-test probe must score inside
/// its `[health]` KL budget against the digital oracle, and the health
/// monitor built on the same rules must report healthy.
#[test]
fn per_class_probe_kl_stays_inside_budget() {
    use memdiff::coordinator::service::ModeGate;
    use memdiff::obs::{obs, HealthConfig, HealthMonitor, ProbeConfig,
                       ProbeRunner};

    // deeper solve than the parity scenarios: the gate scores sample
    // *quality*, so the analog ODE gets a realistic integration window
    let params = CellParams { read_noise_frac: 0.0, ..CellParams::default() };
    let mut reg = EngineRegistry::new();
    reg.add_backend(
        "analog",
        Arc::new(AnalogEngine::new(
            AnalogScoreNet::from_conductances(&weights(), params,
                                              NoiseModel::Ideal),
            sched(),
            400,
        )) as Arc<dyn Engine>,
        1,
    )
    .unwrap();
    reg.add_backend("rust", rust_engine(), 1).unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    let reg = Arc::new(reg);

    let hc = HealthConfig::default();
    let runner = ProbeRunner::new(
        ProbeConfig { samples: hc.probe_samples, steps: hc.probe_steps,
                      seed: hc.probe_seed },
        Arc::clone(&reg));
    let results = runner.run_all();
    assert_eq!(results.len(), 4, "every class routed and probed");
    for r in &results {
        let kl = r.kl.unwrap_or_else(|| {
            panic!("{}:{} not scored: {:?}", r.backend, r.class, r.error)
        });
        let budget = hc.kl_budget[r.class.index()];
        assert!(kl < budget,
                "{}:{} KL {kl:.3} breaches its budget {budget}",
                r.backend, r.class);
        // the scorer exported the gauge the alert rules read
        assert_eq!(
            obs().registry
                .gauge("memdiff_probe_kl",
                       &[("backend", &r.backend), ("class", r.class.name())])
                .get(),
            kl);
    }

    // the monitor over the same deployment agrees: two full probe passes
    // (the alert streak) latch nothing
    let mon = HealthMonitor::new(
        HealthConfig { probe_interval_ms: 0, ..HealthConfig::default() },
        reg, Arc::new(ModeGate::new()));
    mon.probe_now();
    mon.probe_now();
    assert!(mon.healthy(), "healthy deployment alerted: {:?}", mon.firing());
}

/// The ROADMAP's per-class latency gate, mirroring the per-class KL
/// gate above: on the healthy two-backend deployment, every routed
/// class's end-to-end latency (queue wait + solve wall) stays inside
/// its `[slo]` p99 budget — no `slo:` rule latches and every error
/// budget is untouched.  The same cumulative counters breach an
/// absurdly tight budget, so the gate measures rather than
/// rubber-stamps.
#[test]
fn per_class_latency_stays_inside_slo_budget() {
    use memdiff::obs::{AlertEngine, SloConfig, SloEngine};

    memdiff::obs::set_enabled(true);
    let svc = routed_service(NoiseModel::Ideal);
    // the full class cross, paced; the delivery loop records each
    // request's latency into the per-class histograms the engine reads
    for (task, solver, n) in scenario(2) {
        svc.generate(task, n, solver, 2.0, false).unwrap();
    }
    let reg = Arc::clone(svc.registry());

    // the default budgets (30 s p99): every class inside, nothing fires
    let slo = SloEngine::new(SloConfig::default(), Arc::clone(&reg));
    let alerts = AlertEngine::new();
    let states = slo.tick(&alerts);
    assert_eq!(states.len(), 4, "every routed class evaluated");
    for st in &states {
        assert!(st.total >= 2, "{} saw its scenario traffic: {st:?}",
                st.class);
        assert_eq!(st.bad, 0, "{} inside its latency budget: {st:?}",
                   st.class);
        assert!(!st.firing && st.budget_remaining >= 1.0 - 1e-9, "{st:?}");
    }
    assert!(!alerts.any_firing(), "{:?}", alerts.firing());

    // a 1 ns budget with test-scale windows, watching a replay of the
    // scenario: the burn only counts traffic the engine observed inside
    // its windows (a just-born engine scales pre-boot history to
    // nothing), so the baseline tick comes first, then the breaching
    // traffic, then a tick after the slow window is fully covered —
    // every class breaches and its slo:<backend>:<class> rule latches
    let tight = SloEngine::new(
        SloConfig { p99_ms: [1e-6; 4], target_frac: 0.9,
                    fast_window_ms: 50, slow_window_ms: 200,
                    burn_threshold: 1.0, ..SloConfig::default() },
        reg);
    let tight_alerts = AlertEngine::new();
    tight.tick(&tight_alerts); // baseline reading before the breach
    for (task, solver, n) in scenario(2) {
        svc.generate(task, n, solver, 2.0, false).unwrap();
    }
    svc.shutdown();
    std::thread::sleep(std::time::Duration::from_millis(220));
    let breached = tight.tick(&tight_alerts);
    for st in &breached {
        assert!(st.bad > 0 && st.bad <= st.total, "{st:?}");
        assert!(st.firing, "tight budget must latch {}: {st:?}", st.rule);
        assert!(tight_alerts.is_firing(&st.rule), "{}", st.rule);
        let expect_backend =
            if st.class.family == SolverFamily::Analog { "analog" } else { "rust" };
        assert_eq!(st.rule,
                   format!("slo:{expect_backend}:{}", st.class.name()));
    }
}

#[test]
fn routed_service_with_artifact_weights_if_present() {
    // optional heavier check: when the real exported weights exist, the
    // routed deployment serves them the same way (artifact-gated, skips
    // cleanly on fresh checkouts)
    let p = Meta::artifacts_dir().join("weights_cond.json");
    if !p.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let w = ScoreWeights::load(p).unwrap();
    let mut reg = EngineRegistry::new();
    reg.add_backend(
        "analog",
        Arc::new(AnalogEngine::new(
            AnalogScoreNet::from_conductances(
                &w, CellParams::default(), NoiseModel::ReadFast),
            sched(),
            SUBSTEPS,
        )) as Arc<dyn Engine>,
        1,
    )
    .unwrap();
    reg.add_backend(
        "rust",
        Arc::new(RustDigitalEngine { net: DigitalScoreNet::new(w.clone()), sched: sched() })
            as Arc<dyn Engine>,
        1,
    )
    .unwrap();
    reg.route_family(SolverFamily::Analog, "analog").unwrap();
    reg.route_family(SolverFamily::Digital, "rust").unwrap();
    let svc = Service::start_routed(reg, None, svc_cfg());
    let a = svc.generate(TaskKind::Letter(0), 4, SolverChoice::AnalogOde, 2.0, false)
        .unwrap();
    let d = svc
        .generate(TaskKind::Letter(1), 4, SolverChoice::DigitalOde { steps: 16 },
                  2.0, false)
        .unwrap();
    assert_eq!(a.samples.len(), 8);
    assert_eq!(d.samples.len(), 8);
    assert!(a.samples.iter().chain(&d.samples).all(|v| v.is_finite()));
    svc.shutdown();
}
