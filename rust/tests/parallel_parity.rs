//! Bitwise-determinism parity suite for the `exec` bank-parallel
//! subsystem.
//!
//! The contract under test: for any [`exec::Ctx`] — serial, a 1-thread
//! pool, or an N-thread pool, on either the bank (tile-column) or lane
//! axis — every forward path produces **bitwise identical** output.
//! `Ideal` evaluation must additionally equal the serial *monolithic*
//! oracle (the PR 2 invariant, now preserved under parallel execution),
//! and the noisy modes must be thread-count-invariant because every draw
//! comes from a per-bank (or per-lane) stream whose sequence does not
//! depend on scheduling.
//!
//! Runs on synthetic weights so it needs no built artifacts.

use std::sync::Arc;

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::mapper::map_layer;
use memdiff::crossbar::{BankedCrossbarLayer, Banking, CrossbarLayer, NoiseModel};
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::exec::{Ctx, ParStrategy, Pool};
use memdiff::nn::{AnalogScoreNet, BatchScratch, DigitalScoreNet, ScoreNet,
                  ScoreWeights};
use memdiff::util::rng::Rng;
use memdiff::util::tensor::Mat;

fn quiet() -> CellParams {
    CellParams { read_noise_frac: 0.0, ..CellParams::default() }
}

fn test_weights(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| 0.6 * rng.gaussian_f32())
}

/// 1×1, 2×2-ragged and 3×3 tile grids.
const GRIDS: [(usize, usize); 3] = [(32, 32), (40, 40), (96, 96)];

/// The context matrix every parity test sweeps: serial, a 1-thread pool,
/// and a 4-thread pool on each forced axis plus Auto.
fn contexts() -> Vec<(String, Ctx)> {
    let p1 = Arc::new(Pool::new(1));
    let p4 = Arc::new(Pool::new(4));
    vec![
        ("serial".into(), Ctx::serial()),
        ("banks-t1".into(), Ctx::with_pool(ParStrategy::Banks, p1.clone())),
        ("lanes-t1".into(), Ctx::with_pool(ParStrategy::Lanes, p1)),
        ("banks-t4".into(), Ctx::with_pool(ParStrategy::Banks, p4.clone())),
        ("lanes-t4".into(), Ctx::with_pool(ParStrategy::Lanes, p4.clone())),
        ("auto-t4".into(), Ctx::with_pool(ParStrategy::Auto, p4)),
    ]
}

#[test]
fn nthread_banked_ideal_bitwise_equals_serial_monolithic_oracle() {
    for (rows, cols) in GRIDS {
        let w = test_weights(rows, cols, 1000 + rows as u64);
        let m = map_layer(&w);
        let mut mono =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet());
        mono.set_exec(Ctx::serial()); // the oracle stays serial by decree
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..rows).map(|i| (i as f32 * 0.23).sin()).collect();
        let mut want_scalar = vec![0.0f32; cols];
        mono.forward(&v, &mut want_scalar, NoiseModel::Ideal, &mut rng);
        let batch = 7; // odd batch → ragged lane chunks on a 4-thread pool
        let vb: Vec<f32> =
            (0..batch * rows).map(|i| (i as f32 * 0.31).cos() - 0.2).collect();
        let mut want_batch = vec![0.0f32; batch * cols];
        mono.forward_batch(&vb, &mut want_batch, batch, NoiseModel::Ideal,
                           &mut rng);

        for (label, ctx) in contexts() {
            let mut banked = BankedCrossbarLayer::from_conductances(
                &m.g_target, m.gain, quiet(), 11,
            );
            banked.set_exec(ctx);
            let mut got = vec![0.0f32; cols];
            banked.forward(&v, &mut got, NoiseModel::Ideal, &mut rng);
            assert_eq!(got, want_scalar, "{rows}x{cols} scalar under {label}");
            let mut gotb = vec![0.0f32; batch * cols];
            banked.forward_batch(&vb, &mut gotb, batch, NoiseModel::Ideal,
                                 &mut rng);
            assert_eq!(gotb, want_batch, "{rows}x{cols} batched under {label}");
        }
    }
}

#[test]
fn noisy_modes_bitwise_invariant_across_thread_counts() {
    // ReadFast and ReadPerCell draw from per-bank streams, so the outputs
    // (not just their moments) must be identical at any thread count.
    // Fresh layers per context so the stream states start equal; two calls
    // per layer so evolving stream state is covered too.
    for (rows, cols) in GRIDS {
        let w = test_weights(rows, cols, 2000 + cols as u64);
        let m = map_layer(&w);
        let batch = 5;
        let vb: Vec<f32> =
            (0..batch * rows).map(|i| 0.2 + (i as f32 * 0.13).sin()).collect();
        let v: Vec<f32> = vb[..rows].to_vec();
        for noise in [NoiseModel::ReadFast, NoiseModel::ReadPerCell] {
            let mut want: Option<(Vec<f32>, Vec<f32>)> = None;
            for (label, ctx) in contexts() {
                let mut layer = BankedCrossbarLayer::from_conductances(
                    &m.g_target, m.gain, CellParams::default(), 13,
                );
                layer.set_exec(ctx);
                let mut rng = Rng::new(2);
                let mut scalar = vec![0.0f32; cols];
                layer.forward(&v, &mut scalar, noise, &mut rng);
                let mut batched = vec![0.0f32; batch * cols];
                layer.forward_batch(&vb, &mut batched, batch, noise, &mut rng);
                match &want {
                    None => want = Some((scalar, batched)),
                    Some((ws, wb)) => {
                        assert_eq!(&scalar, ws,
                                   "{rows}x{cols} {noise:?} scalar under {label}");
                        assert_eq!(&batched, wb,
                                   "{rows}x{cols} {noise:?} batched under {label}");
                    }
                }
            }
        }
    }
}

#[test]
fn digital_net_lane_chunks_bitwise_at_hidden_48() {
    // hidden = 48, batch 64: big enough that Auto actually forks
    let w = ScoreWeights::synthetic(2, 48, 3, 3000);
    let batch = 64;
    let xs: Vec<f32> =
        (0..batch * 2).map(|i| 0.04 * i as f32 - 1.1).collect();
    let oh = [0.0, 0.0, 1.0];
    let mut want: Option<Vec<f32>> = None;
    for (label, ctx) in contexts() {
        let net = DigitalScoreNet::new(w.clone()).with_exec(ctx);
        let mut rng = Rng::new(3);
        let mut scratch = BatchScratch::new();
        let mut out = vec![0.0f32; batch * 2];
        net.eval_batch(&xs, 0.6, &oh, &mut out, &mut scratch, &mut rng);
        match &want {
            None => {
                // serial context first: cross-check against per-lane eval
                let mut scalar = [0.0f32; 2];
                for b in 0..batch {
                    net.eval(&xs[b * 2..(b + 1) * 2], 0.6, &oh, &mut scalar,
                             &mut rng);
                    assert_eq!(&out[b * 2..(b + 1) * 2], scalar.as_slice(),
                               "lane {b} vs scalar eval");
                }
                want = Some(out);
            }
            Some(w) => assert_eq!(&out, w, "eval_batch under {label}"),
        }
    }
}

#[test]
fn wide_net_end_to_end_bitwise_across_thread_counts() {
    // hidden = 48 score net through the digital sampler AND the analog
    // solver, serial vs 4-thread, against the serial monolithic oracle
    let w = ScoreWeights::synthetic(2, 48, 3, 4000);
    let oh = [0.0, 0.0, 0.0];
    let p4 = Arc::new(Pool::new(4));

    // oracle: forced-monolithic net, serial context
    let mono = AnalogScoreNet::from_conductances_with(
        &w, quiet(), NoiseModel::Ideal, Banking::ForceMonolithic)
        .with_exec(Ctx::serial());

    let mut rng = Rng::new(4);
    let (want_dig, _) = DigitalSampler::new(&mono, SamplerMode::Ode)
        .with_exec(Ctx::serial())
        .sample_batched(6, &oh, 15, &mut rng);
    let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(120);
    let mut rng = Rng::new(5);
    let want_ana = AnalogSolver::new(&mono, cfg.clone())
        .with_exec(Ctx::serial())
        .solve_batched(4, &oh, &mut rng);

    for (label, ctx) in [
        ("serial".to_string(), Ctx::serial()),
        ("auto-t4".to_string(), Ctx::with_pool(ParStrategy::Auto, p4.clone())),
        ("banks-t4".to_string(), Ctx::with_pool(ParStrategy::Banks, p4.clone())),
        ("lanes-t4".to_string(), Ctx::with_pool(ParStrategy::Lanes, p4.clone())),
    ] {
        let banked =
            AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal)
                .with_exec(ctx.clone());
        assert!(banked.is_banked(), "hidden 48 must shard");

        let mut rng = Rng::new(4);
        let (got_dig, _) = DigitalSampler::new(&banked, SamplerMode::Ode)
            .with_exec(ctx.clone())
            .sample_batched(6, &oh, 15, &mut rng);
        assert_eq!(got_dig, want_dig, "digital sampler under {label}");

        let mut rng = Rng::new(5);
        let got_ana = AnalogSolver::new(&banked, cfg.clone())
            .with_exec(ctx)
            .solve_batched(4, &oh, &mut rng);
        assert_eq!(got_ana, want_ana, "analog solver under {label}");
    }
}

#[test]
fn sde_with_read_noise_bitwise_across_thread_counts() {
    // the strongest form of the invariant: device read noise (per-bank
    // streams) + SDE Wiener noise (per-lane streams) end-to-end, still
    // bitwise identical between serial and a 4-thread pool
    let w = ScoreWeights::synthetic(2, 48, 3, 5000);
    let oh = [0.0, 0.0, 0.0];
    let p4 = Arc::new(Pool::new(4));
    let run = |ctx: Ctx| -> (Vec<f32>, Vec<f32>) {
        let net = AnalogScoreNet::from_conductances(
            &w, CellParams::default(), NoiseModel::ReadFast)
            .with_exec(ctx.clone());
        let mut rng = Rng::new(6);
        let (dig, _) = DigitalSampler::new(&net, SamplerMode::Sde)
            .with_exec(ctx.clone())
            .sample_batched(6, &oh, 20, &mut rng);
        let cfg = SolverConfig::new(SolverMode::Sde).with_substeps(80);
        let mut rng = Rng::new(7);
        let ana = AnalogSolver::new(&net, cfg)
            .with_exec(ctx)
            .solve_batched(5, &oh, &mut rng);
        (dig, ana)
    };
    let (want_dig, want_ana) = run(Ctx::serial());
    for strategy in [ParStrategy::Banks, ParStrategy::Auto] {
        let (dig, ana) = run(Ctx::with_pool(strategy, p4.clone()));
        assert_eq!(dig, want_dig, "SDE sampler under {strategy:?}");
        assert_eq!(ana, want_ana, "SDE solver under {strategy:?}");
    }
    assert!(want_dig.iter().all(|v| v.is_finite()));
    assert!(want_ana.iter().all(|v| v.is_finite()));
}
