//! Batched-vs-scalar parity suite: the batched execution lane must be
//! bitwise equal to the per-sample reference lane wherever no randomness
//! enters (`NoiseModel::Ideal`, ODE steppers), and statistically equal
//! (mean/std within estimation tolerance) where it does (`ReadFast`, SDE
//! Wiener noise with per-lane streams).
//!
//! Runs on synthetic weights so it needs no built artifacts.

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::mapper::map_layer;
use memdiff::crossbar::NoiseModel;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerKind, SamplerMode};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::util::rng::Rng;
use memdiff::util::stats;
use memdiff::util::tensor::Mat;

/// Paper-shaped synthetic net (2→14→14→2, 3 classes) with conductances
/// produced by the real mapper, so both realizations deploy consistently.
fn synth_weights(seed: u64) -> ScoreWeights {
    let (dim, hidden, n_classes) = (2usize, 14usize, 3usize);
    let mut rng = Rng::new(seed);
    let w1 = Mat::from_fn(dim, hidden, |_, _| 0.5 * rng.gaussian_f32());
    let w2 = Mat::from_fn(hidden, hidden, |_, _| 0.25 * rng.gaussian_f32());
    let w3 = Mat::from_fn(hidden, dim, |_, _| 0.5 * rng.gaussian_f32());
    let m1 = map_layer(&w1);
    let m2 = map_layer(&w2);
    let m3 = map_layer(&w3);
    let w = ScoreWeights {
        b1: (0..hidden).map(|_| 0.05 * rng.gaussian_f32()).collect(),
        b2: (0..hidden).map(|_| 0.05 * rng.gaussian_f32()).collect(),
        b3: (0..dim).map(|_| 0.05 * rng.gaussian_f32()).collect(),
        emb_w: (0..hidden / 2).map(|i| 0.5 * (i + 1) as f32).collect(),
        cond_proj: Mat::from_fn(n_classes, hidden, |_, _| 0.2 * rng.gaussian_f32()),
        g1: m1.g_target,
        g2: m2.g_target,
        g3: m3.g_target,
        gains: [m1.gain, m2.gain, m3.gain],
        w1,
        w2,
        w3,
    };
    w.validate().unwrap();
    w
}

fn quiet() -> CellParams {
    CellParams { read_noise_frac: 0.0, ..CellParams::default() }
}

#[test]
fn digital_sampler_batched_ode_bitwise_all_steppers() {
    let net = DigitalScoreNet::new(synth_weights(1));
    for kind in [SamplerKind::Euler, SamplerKind::Heun, SamplerKind::Rk4] {
        let sampler = DigitalSampler::new(&net, SamplerMode::Ode).with_kind(kind);
        let mut rng = Rng::new(11);
        let (scalar, ev_s) = sampler.sample_batch(13, &[0.0, 0.0, 0.0], 20, &mut rng);
        let mut rng = Rng::new(11);
        let (batched, ev_b) = sampler.sample_batched(13, &[0.0, 0.0, 0.0], 20, &mut rng);
        assert_eq!(scalar, batched, "{kind:?}");
        assert_eq!(ev_s, ev_b);
    }
}

#[test]
fn digital_sampler_batched_cfg_bitwise() {
    let net = DigitalScoreNet::new(synth_weights(2));
    let sampler = DigitalSampler::new(&net, SamplerMode::Ode).with_guidance(2.0);
    let oh = [0.0, 1.0, 0.0];
    let mut rng = Rng::new(12);
    let (scalar, _) = sampler.sample_batch(9, &oh, 16, &mut rng);
    let mut rng = Rng::new(12);
    let (batched, _) = sampler.sample_batched(9, &oh, 16, &mut rng);
    assert_eq!(scalar, batched);
}

#[test]
fn digital_sampler_batched_sde_statistical_parity() {
    let net = DigitalScoreNet::new(synth_weights(3));
    let sampler = DigitalSampler::new(&net, SamplerMode::Sde);
    let n = 3000;
    let mut rng = Rng::new(13);
    let (scalar, _) = sampler.sample_batch(n, &[0.0, 0.0, 0.0], 64, &mut rng);
    let mut rng = Rng::new(14); // different seed: distribution-level check
    let (batched, _) = sampler.sample_batched(n, &[0.0, 0.0, 0.0], 64, &mut rng);
    for k in 0..2 {
        let xs: Vec<f32> = scalar.iter().skip(k).step_by(2).copied().collect();
        let xb: Vec<f32> = batched.iter().skip(k).step_by(2).copied().collect();
        let (ms, ss) = (stats::mean(&xs), stats::std(&xs));
        let (mb, sb) = (stats::mean(&xb), stats::std(&xb));
        assert!((ms - mb).abs() < 0.1 * ss.max(0.2), "dim {k}: mean {ms} vs {mb}");
        assert!((ss - sb).abs() / ss.max(1e-9) < 0.12, "dim {k}: std {ss} vs {sb}");
    }
}

#[test]
fn analog_solver_batched_ode_ideal_bitwise() {
    let w = synth_weights(4);
    let net = AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
    let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(200);
    let solver = AnalogSolver::new(&net, cfg);
    let mut rng = Rng::new(15);
    let scalar = solver.solve_batch(9, &[0.0, 0.0, 0.0], &mut rng);
    let mut rng = Rng::new(15);
    let batched = solver.solve_batched(9, &[0.0, 0.0, 0.0], &mut rng);
    assert_eq!(scalar, batched);
}

#[test]
fn analog_solver_batched_read_fast_statistical_parity() {
    let w = synth_weights(5);
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(250);
    let solver = AnalogSolver::new(&net, cfg);
    let n = 800;
    let mut rng = Rng::new(16);
    let scalar = solver.solve_batch(n, &[0.0, 0.0, 0.0], &mut rng);
    let mut rng = Rng::new(17);
    let batched = solver.solve_batched(n, &[0.0, 0.0, 0.0], &mut rng);
    for k in 0..2 {
        let xs: Vec<f32> = scalar.iter().skip(k).step_by(2).copied().collect();
        let xb: Vec<f32> = batched.iter().skip(k).step_by(2).copied().collect();
        let (ms, ss) = (stats::mean(&xs), stats::std(&xs));
        let (mb, sb) = (stats::mean(&xb), stats::std(&xb));
        assert!((ms - mb).abs() < 0.15 * ss.max(0.2), "dim {k}: mean {ms} vs {mb}");
        assert!((ss - sb).abs() / ss.max(1e-9) < 0.15, "dim {k}: std {ss} vs {sb}");
    }
}

#[test]
fn batched_ode_lanes_are_batch_prefix_stable() {
    // priors draw lane-by-lane from the base rng, so in ODE mode (where no
    // further randomness enters) the first 5 lanes of a 5-sample batch are
    // bitwise the first 5 lanes of a 13-sample batch: growing the batch
    // cannot disturb earlier lanes.
    let net = DigitalScoreNet::new(synth_weights(6));
    let sampler = DigitalSampler::new(&net, SamplerMode::Ode);
    let mut rng = Rng::new(18);
    let (small, _) = sampler.sample_batched(5, &[0.0, 0.0, 0.0], 24, &mut rng);
    let mut rng = Rng::new(18);
    let (large, _) = sampler.sample_batched(13, &[0.0, 0.0, 0.0], 24, &mut rng);
    assert_eq!(&small[..], &large[..5 * 2],
               "growing the batch must not disturb earlier lanes");
}

#[test]
fn batched_sde_lanes_are_decorrelated() {
    // per-lane Wiener streams: identical priors would still diverge, so
    // with iid priors no two lanes may coincide
    let net = DigitalScoreNet::new(synth_weights(7));
    let sampler = DigitalSampler::new(&net, SamplerMode::Sde);
    let mut rng = Rng::new(19);
    let (pts, _) = sampler.sample_batched(8, &[0.0, 0.0, 0.0], 32, &mut rng);
    for a in 0..8 {
        for b in (a + 1)..8 {
            assert_ne!(&pts[a * 2..a * 2 + 2], &pts[b * 2..b * 2 + 2],
                       "lanes {a} and {b} coincide");
        }
    }
}
