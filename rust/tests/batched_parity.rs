//! Batched-vs-scalar parity suite: the batched execution lane must be
//! bitwise equal to the per-sample reference lane wherever no randomness
//! enters (`NoiseModel::Ideal`, ODE steppers), and statistically equal
//! (mean/std within estimation tolerance) where it does (`ReadFast`, SDE
//! Wiener noise with per-lane streams).
//!
//! The kernel-dispatch sweep extends the same contract across instruction
//! sets: every forced [`KernelBackend`] must be bitwise equal to scalar on
//! the Ideal forward paths (any bank grid, any thread count), and the
//! conductance-quantized i8 lane must be bitwise invariant to backend /
//! banking / chunk plan while staying statistically indistinguishable from
//! the f32 oracle under the `[health]` per-class KL budgets.
//!
//! Runs on synthetic weights so it needs no built artifacts.

use std::sync::{Arc, Mutex};

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::config::{Config, RawConfig};
use memdiff::coordinator::request::RequestClass;
use memdiff::crossbar::mapper::map_layer;
use memdiff::crossbar::{Banking, NoiseModel};
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerKind, SamplerMode};
use memdiff::exec::{Ctx, ParStrategy, Pool};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::util::rng::Rng;
use memdiff::util::simd::{self, KernelBackend};
use memdiff::util::stats;
use memdiff::util::tensor::{self, Mat};
use memdiff::util::KernelMode;

/// Paper-shaped synthetic net (2→14→14→2, 3 classes) with conductances
/// produced by the real mapper, so both realizations deploy consistently.
fn synth_weights(seed: u64) -> ScoreWeights {
    let (dim, hidden, n_classes) = (2usize, 14usize, 3usize);
    let mut rng = Rng::new(seed);
    let w1 = Mat::from_fn(dim, hidden, |_, _| 0.5 * rng.gaussian_f32());
    let w2 = Mat::from_fn(hidden, hidden, |_, _| 0.25 * rng.gaussian_f32());
    let w3 = Mat::from_fn(hidden, dim, |_, _| 0.5 * rng.gaussian_f32());
    let m1 = map_layer(&w1);
    let m2 = map_layer(&w2);
    let m3 = map_layer(&w3);
    let w = ScoreWeights {
        b1: (0..hidden).map(|_| 0.05 * rng.gaussian_f32()).collect(),
        b2: (0..hidden).map(|_| 0.05 * rng.gaussian_f32()).collect(),
        b3: (0..dim).map(|_| 0.05 * rng.gaussian_f32()).collect(),
        emb_w: (0..hidden / 2).map(|i| 0.5 * (i + 1) as f32).collect(),
        cond_proj: Mat::from_fn(n_classes, hidden, |_, _| 0.2 * rng.gaussian_f32()),
        g1: m1.g_target,
        g2: m2.g_target,
        g3: m3.g_target,
        gains: [m1.gain, m2.gain, m3.gain],
        w1,
        w2,
        w3,
    };
    w.validate().unwrap();
    w
}

fn quiet() -> CellParams {
    CellParams { read_noise_frac: 0.0, ..CellParams::default() }
}

#[test]
fn digital_sampler_batched_ode_bitwise_all_steppers() {
    let net = DigitalScoreNet::new(synth_weights(1));
    for kind in [SamplerKind::Euler, SamplerKind::Heun, SamplerKind::Rk4] {
        let sampler = DigitalSampler::new(&net, SamplerMode::Ode).with_kind(kind);
        let mut rng = Rng::new(11);
        let (scalar, ev_s) = sampler.sample_batch(13, &[0.0, 0.0, 0.0], 20, &mut rng);
        let mut rng = Rng::new(11);
        let (batched, ev_b) = sampler.sample_batched(13, &[0.0, 0.0, 0.0], 20, &mut rng);
        assert_eq!(scalar, batched, "{kind:?}");
        assert_eq!(ev_s, ev_b);
    }
}

#[test]
fn digital_sampler_batched_cfg_bitwise() {
    let net = DigitalScoreNet::new(synth_weights(2));
    let sampler = DigitalSampler::new(&net, SamplerMode::Ode).with_guidance(2.0);
    let oh = [0.0, 1.0, 0.0];
    let mut rng = Rng::new(12);
    let (scalar, _) = sampler.sample_batch(9, &oh, 16, &mut rng);
    let mut rng = Rng::new(12);
    let (batched, _) = sampler.sample_batched(9, &oh, 16, &mut rng);
    assert_eq!(scalar, batched);
}

#[test]
fn digital_sampler_batched_sde_statistical_parity() {
    let net = DigitalScoreNet::new(synth_weights(3));
    let sampler = DigitalSampler::new(&net, SamplerMode::Sde);
    let n = 3000;
    let mut rng = Rng::new(13);
    let (scalar, _) = sampler.sample_batch(n, &[0.0, 0.0, 0.0], 64, &mut rng);
    let mut rng = Rng::new(14); // different seed: distribution-level check
    let (batched, _) = sampler.sample_batched(n, &[0.0, 0.0, 0.0], 64, &mut rng);
    for k in 0..2 {
        let xs: Vec<f32> = scalar.iter().skip(k).step_by(2).copied().collect();
        let xb: Vec<f32> = batched.iter().skip(k).step_by(2).copied().collect();
        let (ms, ss) = (stats::mean(&xs), stats::std(&xs));
        let (mb, sb) = (stats::mean(&xb), stats::std(&xb));
        assert!((ms - mb).abs() < 0.1 * ss.max(0.2), "dim {k}: mean {ms} vs {mb}");
        assert!((ss - sb).abs() / ss.max(1e-9) < 0.12, "dim {k}: std {ss} vs {sb}");
    }
}

#[test]
fn analog_solver_batched_ode_ideal_bitwise() {
    let w = synth_weights(4);
    let net = AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
    let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(200);
    let solver = AnalogSolver::new(&net, cfg);
    let mut rng = Rng::new(15);
    let scalar = solver.solve_batch(9, &[0.0, 0.0, 0.0], &mut rng);
    let mut rng = Rng::new(15);
    let batched = solver.solve_batched(9, &[0.0, 0.0, 0.0], &mut rng);
    assert_eq!(scalar, batched);
}

#[test]
fn analog_solver_batched_read_fast_statistical_parity() {
    let w = synth_weights(5);
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(250);
    let solver = AnalogSolver::new(&net, cfg);
    let n = 800;
    let mut rng = Rng::new(16);
    let scalar = solver.solve_batch(n, &[0.0, 0.0, 0.0], &mut rng);
    let mut rng = Rng::new(17);
    let batched = solver.solve_batched(n, &[0.0, 0.0, 0.0], &mut rng);
    for k in 0..2 {
        let xs: Vec<f32> = scalar.iter().skip(k).step_by(2).copied().collect();
        let xb: Vec<f32> = batched.iter().skip(k).step_by(2).copied().collect();
        let (ms, ss) = (stats::mean(&xs), stats::std(&xs));
        let (mb, sb) = (stats::mean(&xb), stats::std(&xb));
        assert!((ms - mb).abs() < 0.15 * ss.max(0.2), "dim {k}: mean {ms} vs {mb}");
        assert!((ss - sb).abs() / ss.max(1e-9) < 0.15, "dim {k}: std {ss} vs {sb}");
    }
}

#[test]
fn batched_ode_lanes_are_batch_prefix_stable() {
    // priors draw lane-by-lane from the base rng, so in ODE mode (where no
    // further randomness enters) the first 5 lanes of a 5-sample batch are
    // bitwise the first 5 lanes of a 13-sample batch: growing the batch
    // cannot disturb earlier lanes.
    let net = DigitalScoreNet::new(synth_weights(6));
    let sampler = DigitalSampler::new(&net, SamplerMode::Ode);
    let mut rng = Rng::new(18);
    let (small, _) = sampler.sample_batched(5, &[0.0, 0.0, 0.0], 24, &mut rng);
    let mut rng = Rng::new(18);
    let (large, _) = sampler.sample_batched(13, &[0.0, 0.0, 0.0], 24, &mut rng);
    assert_eq!(&small[..], &large[..5 * 2],
               "growing the batch must not disturb earlier lanes");
}

/// Serializes mutations of the process-global kernel backend.  Forward
/// paths are order-preserving on every backend, so a concurrently running
/// non-forcing test cannot observe a numeric difference either way — the
/// lock only keeps the forcing tests themselves from racing each other.
static BACKEND_LOCK: Mutex<()> = Mutex::new(());

fn with_backend<R>(b: KernelBackend, f: impl FnOnce() -> R) -> R {
    let _guard = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = simd::active();
    assert!(simd::set_active(b), "backend {b} reported available but refused");
    let r = f();
    simd::set_active(prev);
    r
}

fn exec_for(threads: usize) -> Ctx {
    if threads <= 1 {
        Ctx::serial()
    } else {
        Ctx::with_pool(ParStrategy::Lanes, Arc::new(Pool::new(threads)))
    }
}

fn analog_solve(net: &AnalogScoreNet, n: usize, onehot: &[f32], substeps: usize,
                seed: u64) -> Vec<f32> {
    let cfg = SolverConfig::new(SolverMode::Ode).with_substeps(substeps);
    let mut rng = Rng::new(seed);
    AnalogSolver::new(net, cfg).solve_batched(n, onehot, &mut rng)
}

#[test]
fn kernel_dispatch_matmul_entry_points_bitwise_all_backends() {
    // The three forward-path GEMM entry points vectorize along the output
    // column with scalar-identical accumulation order, so every available
    // backend must reproduce the scalar kernel bit for bit — including
    // ragged shapes that exercise the SIMD remainder loops.
    let mut rng = Rng::new(31);
    for (m, k, n) in [(1usize, 14usize, 14usize), (5, 40, 33), (13, 96, 96), (7, 17, 129)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gaussian_f32()).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut c0 = vec![0.0; m * n];
        let mut cb0 = vec![0.0; m * n];
        let mut ca0 = vec![0.125; m * n];
        tensor::matmul_into_with(KernelBackend::Scalar, &a, &b, &mut c0, m, k, n);
        tensor::matmul_bias_into_with(KernelBackend::Scalar, &a, &b, &bias, &mut cb0, m, k, n);
        tensor::matmul_block_accum_with(KernelBackend::Scalar, &a, k, 0, &b, &mut ca0,
                                        n, 0, m, k, n);
        for backend in simd::available() {
            if backend == KernelBackend::Scalar {
                continue;
            }
            let mut c = vec![0.0; m * n];
            let mut cb = vec![0.0; m * n];
            let mut ca = vec![0.125; m * n];
            tensor::matmul_into_with(backend, &a, &b, &mut c, m, k, n);
            tensor::matmul_bias_into_with(backend, &a, &b, &bias, &mut cb, m, k, n);
            tensor::matmul_block_accum_with(backend, &a, k, 0, &b, &mut ca, n, 0, m, k, n);
            assert_eq!(c, c0, "matmul_into {backend} ({m}x{k}x{n})");
            assert_eq!(cb, cb0, "matmul_bias_into {backend} ({m}x{k}x{n})");
            assert_eq!(ca, ca0, "matmul_block_accum {backend} ({m}x{k}x{n})");
        }
    }
}

#[test]
fn kernel_dispatch_sweep_ideal_bitwise_across_bank_grids() {
    // Forced backends through the full analog stack: 1x1 (14 wide), ragged
    // 2x2 (40 = 32+8) and 3x3 (96 wide) bank grids, monolithic vs banked,
    // serial vs 4-thread lane chunking — one bitwise answer everywhere.
    for (hidden, grid) in [(14usize, "1x1"), (40, "2x2-ragged"), (96, "3x3")] {
        let w = ScoreWeights::synthetic(2, hidden, 3, 100 + hidden as u64);
        let mut reference: Option<Vec<f32>> = None;
        for backend in simd::available() {
            for threads in [1usize, 4] {
                let out = with_backend(backend, || {
                    let mut banked = AnalogScoreNet::from_conductances_with(
                        &w, quiet(), NoiseModel::Ideal, Banking::ForceBanked);
                    banked.set_exec(exec_for(threads));
                    let mut mono = AnalogScoreNet::from_conductances_with(
                        &w, quiet(), NoiseModel::Ideal, Banking::ForceMonolithic);
                    mono.set_exec(exec_for(threads));
                    let ob = analog_solve(&banked, 6, &[0.0, 0.0, 0.0], 60, 21);
                    let om = analog_solve(&mono, 6, &[0.0, 0.0, 0.0], 60, 21);
                    assert_eq!(ob, om,
                               "{grid}: mono vs banked, backend {backend} x{threads}");
                    ob
                });
                match &reference {
                    None => reference = Some(out),
                    Some(r) => assert_eq!(&out, r,
                        "{grid}: backend {backend} x{threads} diverges from scalar"),
                }
            }
        }
    }
}

#[test]
fn quant_lane_bitwise_across_backends_threads_and_banking() {
    // i8 x i8 -> i32 accumulation is exact, so the quantized lane has ONE
    // answer regardless of instruction set, lane chunking, or how the
    // matrix is tiled into banks (per-bank partial sums fold losslessly).
    let w = ScoreWeights::synthetic(2, 40, 3, 77);
    let mut reference: Option<Vec<f32>> = None;
    for backend in simd::available() {
        for threads in [1usize, 4] {
            for banking in [Banking::ForceBanked, Banking::ForceMonolithic] {
                let out = with_backend(backend, || {
                    let mut net = AnalogScoreNet::from_conductances_with(
                        &w, quiet(), NoiseModel::Ideal, banking);
                    net.set_kernel(KernelMode::Quant);
                    net.set_exec(exec_for(threads));
                    analog_solve(&net, 6, &[0.0, 1.0, 0.0], 60, 23)
                });
                match &reference {
                    None => reference = Some(out),
                    Some(r) => assert_eq!(&out, r,
                        "quant lane: backend {backend} x{threads} {banking:?} diverges"),
                }
            }
        }
    }
}

#[test]
fn quant_lane_statistical_parity_and_probe_kl_within_health_budget() {
    // The quantized lane is a different numeric realization of the same
    // score field, so sample clouds drawn from it must match the f32
    // oracle's distribution: mean/std parity per dimension, and the same
    // per-class probe-KL gate the health monitor applies to live engines,
    // with budgets parsed from the `[health]` section.
    let raw = RawConfig::parse(
        "[health]\nkl_budget_analog_uncond = 1.2\nkl_budget_analog_cond = 1.2\n\
         kl_budget_digital_uncond = 1.0\nkl_budget_digital_cond = 1.0\n",
    )
    .unwrap();
    let cfg = Config::from_raw(&raw).unwrap();

    for class in RequestClass::ALL.iter() {
        let budget = cfg.health.kl_budget[class.index()];
        let (cloud, oracle) = match class.name() {
            name @ ("digital_uncond" | "digital_cond") => {
                let cond = name == "digital_cond";
                let onehot = if cond { [0.0, 1.0, 0.0] } else { [0.0; 3] };
                let mut qnet = DigitalScoreNet::new(synth_weights(8));
                qnet.set_kernel(KernelMode::Quant);
                let onet = DigitalScoreNet::new(synth_weights(8));
                let mut sq = DigitalSampler::new(&qnet, SamplerMode::Ode);
                let mut so = DigitalSampler::new(&onet, SamplerMode::Ode);
                if cond {
                    sq = sq.with_guidance(2.0);
                    so = so.with_guidance(2.0);
                }
                let mut rng = Rng::new(51);
                let (cloud, _) = sq.sample_batched(1500, &onehot, 48, &mut rng);
                let mut rng = Rng::new(52); // different seed: distribution-level
                let (oracle, _) = so.sample_batched(1500, &onehot, 48, &mut rng);
                (cloud, oracle)
            }
            name => {
                let cond = name == "analog_cond";
                let onehot = if cond { [0.0, 1.0, 0.0] } else { [0.0; 3] };
                let w = synth_weights(9);
                let mut qnet =
                    AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
                qnet.set_kernel(KernelMode::Quant);
                let onet = AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
                let cloud = analog_solve(&qnet, 500, &onehot, 120, 53);
                let oracle = analog_solve(&onet, 500, &onehot, 120, 54);
                (cloud, oracle)
            }
        };
        // statistical parity: per-dim mean/std within estimation tolerance
        for k in 0..2 {
            let xq: Vec<f32> = cloud.iter().skip(k).step_by(2).copied().collect();
            let xo: Vec<f32> = oracle.iter().skip(k).step_by(2).copied().collect();
            let (mq, sq) = (stats::mean(&xq), stats::std(&xq));
            let (mo, so) = (stats::mean(&xo), stats::std(&xo));
            assert!((mq - mo).abs() < 0.15 * so.max(0.2),
                    "{}: dim {k} mean {mq} vs {mo}", class.name());
            assert!((sq - so).abs() / so.max(1e-9) < 0.15,
                    "{}: dim {k} std {sq} vs {so}", class.name());
        }
        // probe-KL gate: same statistic and budgets the health monitor uses
        let kl = stats::kl_points(&cloud, &oracle, 24, 2.0);
        assert!(kl.is_finite() && kl < budget,
                "{}: probe KL {kl:.3} exceeds budget {budget}", class.name());
    }
}

#[test]
fn batched_sde_lanes_are_decorrelated() {
    // per-lane Wiener streams: identical priors would still diverge, so
    // with iid priors no two lanes may coincide
    let net = DigitalScoreNet::new(synth_weights(7));
    let sampler = DigitalSampler::new(&net, SamplerMode::Sde);
    let mut rng = Rng::new(19);
    let (pts, _) = sampler.sample_batched(8, &[0.0, 0.0, 0.0], 32, &mut rng);
    for a in 0..8 {
        for b in (a + 1)..8 {
            assert_ne!(&pts[a * 2..a * 2 + 2], &pts[b * 2..b * 2 + 2],
                       "lanes {a} and {b} coincide");
        }
    }
}
