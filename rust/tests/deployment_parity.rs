//! Deployment-contract integration tests: the rust mapper must reproduce
//! the python export exactly, and the digital/analog nets must agree on
//! the deployed function.

use memdiff::crossbar::{self, NoiseModel};
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreNet, ScoreWeights};
use memdiff::util::rng::Rng;
use memdiff::util::tensor::Mat;

fn weights() -> Option<ScoreWeights> {
    let p = Meta::artifacts_dir().join("weights_uncond.json");
    if !p.exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(ScoreWeights::load(p).unwrap())
}

#[test]
fn rust_mapper_reproduces_python_conductances() {
    // g_i in the artifact == quantize(w_i/gain_i + G_FIXED) with the rust
    // mapper — the two implementations of the deployment pipeline agree.
    let Some(w) = weights() else { return };
    for (wm, gm, gain) in [(&w.w1, &w.g1, w.gains[0]),
                           (&w.w2, &w.g2, w.gains[1]),
                           (&w.w3, &w.g3, w.gains[2])] {
        let ours = crossbar::mapper::quantize(
            &crossbar::weight_to_conductance(wm, gain));
        let diff = ours.max_abs_diff(gm);
        assert!(diff < 1e-6, "conductance mismatch {diff}");
    }
}

#[test]
fn rust_gain_close_to_python_gain() {
    // with QAT the exported weights already sit inside the window; the
    // rust required_gain recomputed from them must match the python one
    let Some(w) = weights() else { return };
    for (wm, gain) in [(&w.w1, w.gains[0]), (&w.w2, w.gains[1]), (&w.w3, w.gains[2])] {
        let ours = crossbar::required_gain(wm);
        assert!(
            (ours / gain - 1.0).abs() < 0.02,
            "gain {ours} vs python {gain}"
        );
    }
}

#[test]
fn digital_and_analog_nets_agree_on_deployed_function() {
    // DigitalScoreNet on conductance-implied weights == AnalogScoreNet
    // (ideal, no read noise) up to the 12-bit embedding DAC.
    let Some(w) = weights() else { return };
    let implied = ScoreWeights {
        w1: crossbar::conductance_to_weight(&w.g1, w.gains[0]),
        w2: crossbar::conductance_to_weight(&w.g2, w.gains[1]),
        w3: crossbar::conductance_to_weight(&w.g3, w.gains[2]),
        ..w.clone()
    };
    let digital = DigitalScoreNet::new(implied);
    let params = CellParams { read_noise_frac: 0.0, ..CellParams::default() };
    let analog = AnalogScoreNet::from_conductances(&w, params, NoiseModel::Ideal);
    let mut rng = Rng::new(0);
    let (mut a, mut d) = ([0.0f32; 2], [0.0f32; 2]);
    for i in 0..30 {
        let x = [(i as f32 - 15.0) / 10.0, ((i * 3 % 7) as f32 - 3.0) / 4.0];
        let t = 0.02 + 0.96 * i as f32 / 29.0;
        analog.eval(&x, t, &[0.0, 0.0, 0.0], &mut a, &mut rng);
        digital.eval(&x, t, &[0.0, 0.0, 0.0], &mut d, &mut rng);
        for k in 0..2 {
            // remaining physical deltas: diode soft-knee ReLU (≤ KNEE·ln2
            // ≈ 0.014 per hidden unit near zero) + 12-bit embedding DAC
            assert!((a[k] - d[k]).abs() < 3e-2, "i={i} k={k}: {} vs {}", a[k], d[k]);
        }
    }
}

#[test]
fn qat_kept_deployment_error_negligible() {
    // weight-space net vs conductance-implied net: after QAT training the
    // two functions must be close (this is the entire point of QAT).
    let Some(w) = weights() else { return };
    let implied = ScoreWeights {
        w1: crossbar::conductance_to_weight(&w.g1, w.gains[0]),
        w2: crossbar::conductance_to_weight(&w.g2, w.gains[1]),
        w3: crossbar::conductance_to_weight(&w.g3, w.gains[2]),
        ..w.clone()
    };
    // weights themselves match within half a quantization step
    for ((wm, im), gain) in [(&w.w1, &implied.w1), (&w.w2, &implied.w2), (&w.w3, &implied.w3)]
        .into_iter()
        .zip(w.gains)
    {
        let qstep = gain * 0.08 / 63.0;
        assert!(
            wm.max_abs_diff(im) <= 0.5 * qstep + 1e-5,
            "deployment weight error {} > half-step {}",
            wm.max_abs_diff(im),
            0.5 * qstep
        );
    }
}

#[test]
fn programming_write_verify_close_to_exact_deployment() {
    // program_from_weights (write noise path) lands near from_conductances
    let Some(w) = weights() else { return };
    let quiet = CellParams { read_noise_frac: 0.0, ..CellParams::default() };
    let exact = AnalogScoreNet::from_conductances(&w, quiet.clone(), NoiseModel::Ideal);
    let mut rng = Rng::new(9);
    let (programmed, pulses) = AnalogScoreNet::program_from_weights(
        &w, quiet, 0.0008, NoiseModel::Ideal, &mut rng);
    assert!(pulses > 200, "write-verify should need real work: {pulses}");
    let (e1, _, _) = exact.effective_weights();
    let (p1, _, _) = programmed.effective_weights();
    let diff = e1.max_abs_diff(&p1);
    let qstep = w.gains[0] * 0.08 / 63.0;
    assert!(diff < 3.0 * qstep, "programmed weight error {diff}");
}

#[test]
fn conductances_land_on_levels() {
    let Some(w) = weights() else { return };
    let step = 0.08f32 / 63.0;
    for g in [&w.g1, &w.g2, &w.g3] {
        for &x in g.as_slice() {
            let k = (x - 0.02) / step;
            assert!((k - k.round()).abs() < 1e-3, "conductance {x} off-grid");
        }
    }
}

#[test]
fn mat_helper_shapes() {
    // guard for the Mat-based helpers used above
    let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
    assert_eq!(m.shape(), (2, 2));
}
