"""VAE: encoder/decoder shapes, Eq. 10 loss, latent clustering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, vae


@pytest.fixture(scope="module")
def params():
    return vae.init_vae(jax.random.PRNGKey(0))


def test_encode_shapes(params):
    x = jnp.zeros((7, datasets.IMG * datasets.IMG))
    mu, lv = vae.encode(params, x)
    assert mu.shape == (7, vae.LATENT)
    assert lv.shape == (7, vae.LATENT)


def test_decode_shape_and_range(params):
    z = jnp.asarray(np.random.default_rng(0).standard_normal((5, 2)), jnp.float32)
    img = np.asarray(vae.decode(params, z))
    assert img.shape == (5, datasets.IMG, datasets.IMG)
    assert np.abs(img).max() <= 1.0  # tanh output


def test_vae_loss_finite(params):
    imgs, labels = datasets.letters_dataset(8, seed=0)
    l = float(vae.vae_loss(params, jax.random.PRNGKey(1),
                           jnp.asarray(imgs), jnp.asarray(labels)))
    assert np.isfinite(l) and l > 0


def test_training_reduces_loss_and_clusters():
    imgs, labels = datasets.letters_dataset(96, seed=0)
    p0 = vae.init_vae(jax.random.PRNGKey(5))
    l0 = float(vae.vae_loss(p0, jax.random.PRNGKey(0),
                            jnp.asarray(imgs[:64]), jnp.asarray(labels[:64])))
    p1, l1 = vae.train_vae(jax.random.PRNGKey(5), imgs, labels,
                           steps=600, batch=96)
    assert l1 < l0
    # Eq. 10's KL term must produce *separated* class clusters (the preset
    # centers are only reached asymptotically with full-length training;
    # meta.json records the actual trained means for downstream eval)
    lat = vae.encode_dataset(p1, imgs)
    means = [lat[labels == c].mean(axis=0) for c in range(3)]
    for i in range(3):
        for j in range(i + 1, 3):
            sep = float(np.linalg.norm(means[i] - means[j]))
            assert sep > 0.7, f"classes {i},{j} not separated: {sep}"


def test_decoder_dict_layout(params):
    d = vae.decoder_dict(params)
    assert set(d) == {"lin_w", "lin_b", "dc1_w", "dc1_b", "dc2_w", "dc2_b"}
    assert d["lin_w"].shape == (vae.LATENT, 3 * 3 * vae.DEC_C1)
    assert d["dc1_w"].shape == (4, 4, vae.DEC_C1, vae.DEC_C2)
    assert d["dc2_w"].shape == (4, 4, vae.DEC_C2, 1)
