"""Score-model semantics: weight/conductance-space equivalence, CFG, DSM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import analog, model
from compile.kernels import ref
from compile.schedule import DEFAULT as SCHED


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


def test_score_fwd_shapes(params):
    x = jnp.zeros((5, model.DIM))
    t = jnp.linspace(0.1, 0.9, 5)
    out = model.score_fwd(params, x, t)
    assert out.shape == (5, model.DIM)


def test_embedding_sum_condition(params):
    """Conditional embedding must be time-embedding + projected one-hot (Fig. 4b)."""
    t = jnp.array([0.4, 0.6])
    oh = jax.nn.one_hot(jnp.array([1, 2]), model.N_CLASSES)
    e = np.asarray(model.make_embedding(params, t, oh))
    e_t = np.asarray(model.make_embedding(params, t))
    e_c = np.asarray(oh @ params.cond_proj)
    np.testing.assert_allclose(e, e_t + e_c, rtol=1e-6)


def test_cfg_lambda_zero_is_conditional(params):
    """Eq. 7 with lam=0 reduces to the conditional score."""
    x = jnp.ones((4, 2)) * 0.2
    t = jnp.full((4,), 0.5)
    oh = jax.nn.one_hot(jnp.array([0, 1, 2, 0]), 3)
    a = np.asarray(model.cfg_score(params, x, t, oh, 0.0))
    b = np.asarray(model.score_fwd(params, x, t, oh))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_cfg_extrapolates(params):
    """(1+lam) s_c - lam s_u: lam=1 doubles the conditional pull."""
    x = jnp.ones((1, 2)) * 0.1
    t = jnp.full((1,), 0.5)
    oh = jax.nn.one_hot(jnp.array([1]), 3)
    s_c = np.asarray(model.score_fwd(params, x, t, oh))
    s_u = np.asarray(model.score_fwd(params, x, t, jnp.zeros_like(oh)))
    got = np.asarray(model.cfg_score(params, x, t, oh, 1.0))
    np.testing.assert_allclose(got, 2 * s_c - s_u, rtol=1e-5)


def test_analog_equals_weight_space_after_mapping(params):
    """Deployment contract: conductance-space fwd == weight-space fwd up to
    64-level quantization error."""
    gp = analog.map_to_conductance(params)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 2)), jnp.float32)
    t = jnp.linspace(0.05, 0.95, 64)
    want = np.asarray(model.score_fwd(params, x, t))
    got = np.asarray(model.score_fwd_analog(gp, params, x, t))
    # quantization step in weight space = gain * window/63, per layer
    qstep = max(gp["gains"]) * (ref.G_CELL_HI_MS - ref.G_CELL_LO_MS) / 63
    tol = 10 * qstep  # worst-case accumulation over 3 tiny layers
    np.testing.assert_allclose(got, want, atol=tol)


def test_dsm_loss_decreases_under_training():
    rng = np.random.default_rng(0)
    from compile import datasets
    data = datasets.sample_circle(2048, rng)
    p0 = model.init_params(jax.random.PRNGKey(1))
    l0 = float(model.dsm_loss(p0, jax.random.PRNGKey(2), jnp.asarray(data[:512])))
    p1, l1 = model.train_score(jax.random.PRNGKey(1), data, steps=300, batch=256)
    assert l1 < l0


def test_sample_respects_state_clamp(params):
    out = np.asarray(model.sample(params, jax.random.PRNGKey(0), 64, n_steps=20))
    assert out.min() >= ref.V_CLAMP_LO - 1e-6
    assert out.max() <= ref.V_CLAMP_HI + 1e-6


def test_score_from_net_sign():
    """score = -net/sigma: positive net must give negative score."""
    s = np.asarray(model.score_from_net(jnp.ones((2, 2)), 0.5))
    np.testing.assert_allclose(s, -2.0)
