"""Weight <-> conductance mapping and noise-model statistics."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from compile import analog, model
from compile.kernels import ref


def test_required_gain_fits_window():
    rng = np.random.default_rng(0)
    ws = [rng.standard_normal((5, 7)).astype(np.float32) for _ in range(3)]
    gain = analog.required_gain(ws)
    for w in ws:
        g = analog.weight_to_conductance(w, gain)
        assert g.min() >= ref.G_CELL_LO_MS - 1e-9
        assert g.max() <= ref.G_CELL_HI_MS + 1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), scale=st.floats(0.01, 10.0))
def test_roundtrip_within_quantization(seed, scale):
    rng = np.random.default_rng(seed)
    w = (scale * rng.standard_normal((8, 8))).astype(np.float32)
    gain = analog.required_gain([w])
    g = analog.quantize(analog.weight_to_conductance(w, gain))
    w2 = analog.conductance_to_weight(g, gain)
    qstep = gain * (ref.G_CELL_HI_MS - ref.G_CELL_LO_MS) / (ref.N_LEVELS - 1)
    assert np.abs(w2 - w).max() <= 0.5 * qstep + 1e-6


def test_quantize_snaps_to_levels():
    g = np.linspace(ref.G_CELL_LO_MS, ref.G_CELL_HI_MS, 1000)
    q = analog.quantize(g)
    assert len(np.unique(np.round(q, 9))) <= ref.N_LEVELS
    step = (ref.G_CELL_HI_MS - ref.G_CELL_LO_MS) / (ref.N_LEVELS - 1)
    k = (q - ref.G_CELL_LO_MS) / step
    np.testing.assert_allclose(k, np.round(k), atol=1e-4)


def test_write_noise_statistics():
    rng = np.random.default_rng(1)
    g = np.full(200_000, 0.06, dtype=np.float32)
    gn = analog.add_write_noise(g, rng)
    resid = gn - g
    assert abs(resid.mean()) < 2e-5
    # truncated at 2 sigma => std slightly below nominal
    assert 0.7 * analog.WRITE_NOISE_STD_MS < resid.std() < analog.WRITE_NOISE_STD_MS
    assert np.abs(resid).max() <= 2.0 * analog.WRITE_NOISE_STD_MS + 1e-9


def test_read_noise_proportional_to_g():
    """Fig. 2e/5c: read fluctuation scales with the mean conductance."""
    rng = np.random.default_rng(2)
    lo = analog.add_read_noise(np.full(100_000, 0.02, np.float32), rng) - 0.02
    hi = analog.add_read_noise(np.full(100_000, 0.10, np.float32), rng) - 0.10
    assert hi.std() > 3 * lo.std()
    np.testing.assert_allclose(hi.std(), 0.10 * analog.READ_NOISE_FRAC, rtol=0.1)


def test_map_to_conductance_structure():
    p = model.init_params(jax.random.PRNGKey(0))
    gp = analog.map_to_conductance(p)
    assert set(gp) == {"g1", "g2", "g3", "b1", "b2", "b3", "gains"}
    assert len(gp["gains"]) == 3
    assert gp["g1"].shape == (model.DIM, model.HIDDEN)
    assert gp["g2"].shape == (model.HIDDEN, model.HIDDEN)
    assert gp["g3"].shape == (model.HIDDEN, model.DIM)
    for k in ("g1", "g2", "g3"):
        assert gp[k].min() >= ref.G_CELL_LO_MS - 1e-9
        assert gp[k].max() <= ref.G_CELL_HI_MS + 1e-9


def test_write_noise_degrades_gracefully():
    """Programming error perturbs the forward pass boundedly (Fig. 5e premise)."""
    import jax.numpy as jnp
    p = model.init_params(jax.random.PRNGKey(3))
    clean = analog.map_to_conductance(p)
    noisy = analog.map_to_conductance(p, write_noise_rng=np.random.default_rng(0))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((32, 2)), jnp.float32)
    t = jnp.full((32,), 0.5)
    a = np.asarray(model.score_fwd_analog(clean, p, x, t))
    b = np.asarray(model.score_fwd_analog(noisy, p, x, t))
    d = np.abs(a - b).max()
    assert 0 < d < 1.0  # perturbed, but not destroyed
