"""VP-SDE schedule invariants."""

import numpy as np
import pytest

from compile.schedule import DEFAULT, EPS_T, VpSchedule


def test_beta_endpoints():
    s = DEFAULT
    assert np.isclose(float(s.beta(0.0)), s.beta_min)
    assert np.isclose(float(s.beta(s.t_end)), s.beta_max)


def test_beta_monotone():
    s = DEFAULT
    ts = np.linspace(0, s.t_end, 100)
    bs = np.asarray([float(s.beta(t)) for t in ts])
    assert (np.diff(bs) > 0).all()


def test_alpha_sigma_variance_preserving():
    """alpha^2 + sigma^2 == 1 for all t (the VP property)."""
    s = DEFAULT
    for t in np.linspace(EPS_T, s.t_end, 37):
        a, sg = float(s.alpha(t)), float(s.sigma(t))
        assert np.isclose(a * a + sg * sg, 1.0, atol=1e-6)


def test_int_beta_matches_numeric():
    s = DEFAULT
    ts = np.linspace(0, s.t_end, 2001)
    num = np.cumsum([float(s.beta(t)) for t in ts]) * (ts[1] - ts[0])
    assert np.isclose(float(s.int_beta(s.t_end)), num[-1], rtol=2e-3)


def test_terminal_marginal_is_near_gaussian():
    """The deviation fix: alpha(T) must be small so N(0,I) is a valid prior."""
    assert float(DEFAULT.alpha(DEFAULT.t_end)) < 0.1


def test_paper_quoted_range_available():
    """The quoted beta_max=0.5 stays constructible for the ablation benches."""
    s = VpSchedule(beta_max=0.5)
    assert np.isclose(float(s.beta(1.0)), 0.5)
    assert float(s.alpha(1.0)) > 0.8  # and indeed barely diffuses


def test_ode_sde_rhs_relation():
    """F_SDE - F_ODE == -(1/2) g^2 score (Eq. 1 vs Eq. 2)."""
    s = DEFAULT
    x = np.array([[0.3, -0.7]], dtype=np.float32)
    score = np.array([[1.1, 0.2]], dtype=np.float32)
    for t in [0.1, 0.5, 0.9]:
        d = np.asarray(s.reverse_sde_rhs(x, t, score) -
                       s.reverse_ode_rhs(x, t, score))
        want = -0.5 * float(s.beta(t)) * score
        np.testing.assert_allclose(d, want, rtol=1e-5)


def test_g2_over_sigma_positive_finite():
    s = DEFAULT
    for t in np.linspace(EPS_T, s.t_end, 50):
        v = float(s.g2_over_sigma(t))
        assert np.isfinite(v) and v > 0
