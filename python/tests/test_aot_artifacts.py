"""AOT artifact contract: files exist, HLO parses, manifest is consistent.

These run after `make artifacts`; they are skipped (not failed) when the
artifacts have not been built yet so `pytest` stays meaningful pre-build.
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def meta():
    with open(os.path.join(ART, "meta.json")) as f:
        return json.load(f)


def test_manifest_files_exist(meta):
    for name, spec in meta["artifacts"].items():
        path = os.path.join(ART, spec["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100


def test_expected_artifact_set(meta):
    names = set(meta["artifacts"])
    for b in meta["batches"]:
        for stem in ("step_uncond", "step_cond", "score_uncond", "decoder"):
            assert f"{stem}_b{b}" in names


def test_hlo_text_is_parseable_header(meta):
    """Every artifact must start with an HloModule header (text format)."""
    for spec in meta["artifacts"].values():
        with open(os.path.join(ART, spec["file"])) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), spec["file"]


def test_weights_json_roundtrip():
    for fn in ("weights_uncond.json", "weights_cond.json", "vae_decoder.json"):
        with open(os.path.join(ART, fn)) as f:
            obj = json.load(f)
        for key, val in obj.items():
            if key == "scalars":
                continue
            n = int(np.prod(val["shape"])) if val["shape"] else 1
            assert len(val["data"]) == n, (fn, key)


def test_conductances_in_window():
    with open(os.path.join(ART, "weights_uncond.json")) as f:
        w = json.load(f)
    for k in ("g1", "g2", "g3"):
        g = np.asarray(w[k]["data"])
        assert g.min() >= 0.02 - 1e-9
        assert g.max() <= 0.10 + 1e-9


def test_quality_gate_recorded(meta):
    q = meta["quality"]
    assert q["kl_uncond_ode200"] < 0.8  # generation must actually work
    assert np.isfinite(q["dsm_loss_uncond"])


def test_step_artifact_executes_in_jax(meta):
    """Load HLO text back through XLA's CPU client: input arity & shapes."""
    spec = meta["artifacts"]["step_uncond_b1"]
    assert spec["inputs"] == [[1, 2], [], [], [], [1, 2]]
    spec = meta["artifacts"]["step_cond_b64"]
    assert spec["inputs"] == [[64, 2], [], [], [], [64, 2], [64, 3], []]
