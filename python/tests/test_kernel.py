"""Kernel-vs-ref correctness: the CORE L1 signal.

Hypothesis sweeps shapes, seeds, gains and epilogues of every Pallas kernel
against the pure-jnp oracles in ``compile.kernels.ref``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.crossbar import crossbar_mvm_kernel
from compile.kernels.score_mlp import score_mlp_kernel
from compile.kernels.integrator import euler_step_kernel
from compile.kernels.deconv import deconv2d_kernel

HSETTINGS = dict(max_examples=20, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# --- crossbar ----------------------------------------------------------------

@settings(**HSETTINGS)
@given(b=st.integers(1, 97), n_in=st.integers(1, 32), n_out=st.integers(1, 32),
       gain=st.floats(0.5, 50.0), relu=st.booleans(), seed=st.integers(0, 2**31))
def test_crossbar_matches_ref(b, n_in, n_out, gain, relu, seed):
    rng = _rng(seed)
    v = (3.0 * rng.standard_normal((b, n_in))).astype(np.float32)
    g = rng.uniform(ref.G_CELL_LO_MS, ref.G_CELL_HI_MS,
                    (n_in, n_out)).astype(np.float32)
    got = crossbar_mvm_kernel(v, g, tia_gain=gain, relu=relu)
    want = ref.crossbar_mvm(v, g, gain, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_crossbar_clamps_input():
    """Voltages beyond the protective window must be clipped, not passed."""
    v = np.array([[10.0, -10.0]], dtype=np.float32)
    g = np.full((2, 1), 0.06, dtype=np.float32)
    got = np.asarray(crossbar_mvm_kernel(v, g, tia_gain=1.0))
    want = (4.0 + -2.0) * (0.06 - ref.G_FIXED_MS)
    np.testing.assert_allclose(got[0, 0], want, rtol=1e-5)


def test_crossbar_zero_weight_at_gfixed():
    """A cell programmed exactly to G_FIXED is a zero weight (differential pair)."""
    v = np.ones((4, 3), dtype=np.float32)
    g = np.full((3, 2), ref.G_FIXED_MS, dtype=np.float32)
    got = np.asarray(crossbar_mvm_kernel(v, g))
    np.testing.assert_allclose(got, 0.0, atol=1e-7)


# --- fused score MLP ----------------------------------------------------------

def _score_params(rng, hidden=14, dim=2):
    return dict(
        w1=rng.uniform(ref.G_CELL_LO_MS, ref.G_CELL_HI_MS, (dim, hidden)).astype(np.float32),
        b1=(0.3 * rng.standard_normal(hidden)).astype(np.float32),
        w2=rng.uniform(ref.G_CELL_LO_MS, ref.G_CELL_HI_MS, (hidden, hidden)).astype(np.float32),
        b2=(0.3 * rng.standard_normal(hidden)).astype(np.float32),
        w3=rng.uniform(ref.G_CELL_LO_MS, ref.G_CELL_HI_MS, (hidden, dim)).astype(np.float32),
        b3=(0.3 * rng.standard_normal(dim)).astype(np.float32),
    )


@settings(**HSETTINGS)
@given(b=st.integers(1, 70), hidden=st.sampled_from([6, 14, 20]),
       gain=st.floats(1.0, 40.0), seed=st.integers(0, 2**31))
def test_score_mlp_matches_ref(b, hidden, gain, seed):
    rng = _rng(seed)
    p = _score_params(rng, hidden)
    x = (2.0 * rng.standard_normal((b, 2))).astype(np.float32)
    emb = rng.standard_normal((b, hidden)).astype(np.float32)
    got = score_mlp_kernel(x, emb, p["w1"], p["b1"], p["w2"], p["b2"],
                           p["w3"], p["b3"], tia_gain=gain)
    want = ref.score_mlp(x, emb, p, gain)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_score_mlp_embedding_injection():
    """Zero embedding vs nonzero embedding must differ (bias-current path)."""
    rng = _rng(0)
    p = _score_params(rng)
    x = rng.standard_normal((8, 2)).astype(np.float32)
    e0 = np.zeros((8, 14), dtype=np.float32)
    e1 = np.ones((8, 14), dtype=np.float32)
    a = np.asarray(score_mlp_kernel(x, e0, p["w1"], p["b1"], p["w2"], p["b2"],
                                    p["w3"], p["b3"], tia_gain=10.0))
    bb = np.asarray(score_mlp_kernel(x, e1, p["w1"], p["b1"], p["w2"], p["b2"],
                                     p["w3"], p["b3"], tia_gain=10.0))
    assert np.abs(a - bb).max() > 1e-4


# --- integrator step -----------------------------------------------------------

@settings(**HSETTINGS)
@given(b=st.integers(1, 97), d=st.integers(1, 8),
       beta=st.floats(1e-3, 12.0), dt=st.floats(1e-4, 0.1),
       mode=st.sampled_from([0.0, 1.0]), seed=st.integers(0, 2**31))
def test_euler_step_matches_ref(b, d, beta, dt, mode, seed):
    rng = _rng(seed)
    x = rng.standard_normal((b, d)).astype(np.float32)
    s = rng.standard_normal((b, d)).astype(np.float32)
    z = rng.standard_normal((b, d)).astype(np.float32)
    got = euler_step_kernel(x, s, z, beta, dt, mode)
    want = ref.euler_step(x, s, beta, dt, z, mode)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_euler_ode_ignores_noise():
    rng = _rng(1)
    x = rng.standard_normal((16, 2)).astype(np.float32)
    s = rng.standard_normal((16, 2)).astype(np.float32)
    z1 = rng.standard_normal((16, 2)).astype(np.float32)
    z2 = rng.standard_normal((16, 2)).astype(np.float32)
    a = np.asarray(euler_step_kernel(x, s, z1, 0.5, 0.01, 0.0))
    b = np.asarray(euler_step_kernel(x, s, z2, 0.5, 0.01, 0.0))
    np.testing.assert_allclose(a, b, atol=1e-7)


def test_euler_sde_noise_scale():
    """Wiener increment must enter with sqrt(beta*dt) magnitude."""
    x = np.zeros((1, 2), dtype=np.float32)
    s = np.zeros((1, 2), dtype=np.float32)
    z = np.ones((1, 2), dtype=np.float32)
    beta, dt = 0.4, 0.01
    got = np.asarray(euler_step_kernel(x, s, z, beta, dt, 1.0))
    np.testing.assert_allclose(got, np.sqrt(beta * dt), rtol=1e-5)


# --- deconv ---------------------------------------------------------------------

@settings(**HSETTINGS)
@given(b=st.integers(1, 9), side=st.sampled_from([3, 6]),
       ci=st.integers(1, 8), co=st.integers(1, 8), seed=st.integers(0, 2**31))
def test_deconv_matches_ref(b, side, ci, co, seed):
    rng = _rng(seed)
    x = rng.standard_normal((b, side, side, ci)).astype(np.float32)
    w = (0.2 * rng.standard_normal((4, 4, ci, co))).astype(np.float32)
    bias = (0.1 * rng.standard_normal(co)).astype(np.float32)
    got = deconv2d_kernel(x, w, bias)
    want = ref.deconv2d(x, w, bias)
    assert got.shape == (b, 2 * side, 2 * side, co)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_deconv_epilogues():
    rng = _rng(3)
    x = rng.standard_normal((2, 3, 3, 4)).astype(np.float32)
    w = rng.standard_normal((4, 4, 4, 2)).astype(np.float32)
    bias = np.zeros(2, dtype=np.float32)
    r = np.asarray(deconv2d_kernel(x, w, bias, relu=True))
    t = np.asarray(deconv2d_kernel(x, w, bias, tanh=True))
    assert (r >= 0).all()
    assert (np.abs(t) <= 1.0).all()
    base = np.asarray(ref.deconv2d(x, w, bias))
    np.testing.assert_allclose(r, np.maximum(base, 0), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(t, np.tanh(base), rtol=2e-4, atol=2e-4)


def test_deconv_upsamples_exactly_2x():
    """kernel 4 / stride 2 / pad 1 doubles the spatial side — decoder geometry 3->6->12."""
    x = np.ones((1, 3, 3, 1), dtype=np.float32)
    w = np.ones((4, 4, 1, 1), dtype=np.float32)
    out = deconv2d_kernel(x, w, np.zeros(1, dtype=np.float32))
    assert out.shape == (1, 6, 6, 1)
