"""Synthetic dataset invariants (circle + EMNIST-substitute glyphs)."""

import numpy as np
import pytest

from compile import datasets


def test_circle_radius_statistics():
    rng = np.random.default_rng(0)
    x = datasets.sample_circle(50_000, rng, radius=1.0, radial_std=0.05)
    r = np.hypot(x[:, 0], x[:, 1])
    assert abs(r.mean() - 1.0) < 0.01
    assert abs(r.std() - 0.05) < 0.01


def test_circle_angle_uniform():
    rng = np.random.default_rng(1)
    x = datasets.sample_circle(50_000, rng)
    theta = np.arctan2(x[:, 1], x[:, 0])
    hist, _ = np.histogram(theta, bins=16, range=(-np.pi, np.pi))
    assert hist.min() > 0.8 * hist.mean()


def test_letters_shapes_and_range():
    imgs, labels = datasets.letters_dataset(32, seed=0)
    assert imgs.shape == (96, datasets.IMG, datasets.IMG)
    assert labels.shape == (96,)
    assert imgs.min() >= -1.0 and imgs.max() <= 1.0
    assert set(np.unique(labels)) == {0, 1, 2}


def test_letters_classes_distinct():
    """Mean glyphs of the three classes must be visually distinct."""
    imgs, labels = datasets.letters_dataset(64, seed=1)
    means = [imgs[labels == c].mean(axis=0) for c in range(3)]
    for i in range(3):
        for j in range(i + 1, 3):
            assert np.abs(means[i] - means[j]).mean() > 0.05


def test_letters_deterministic_per_seed():
    a, la = datasets.letters_dataset(8, seed=3)
    b, lb = datasets.letters_dataset(8, seed=3)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


def test_letters_vary_within_class():
    imgs, labels = datasets.letters_dataset(16, seed=4)
    h = imgs[labels == 0]
    assert np.abs(h[0] - h[1]).max() > 0.1  # affine jitter present


def test_class_centers_separated():
    c = datasets.CLASS_CENTERS
    for i in range(3):
        for j in range(i + 1, 3):
            assert np.linalg.norm(c[i] - c[j]) > 2.0
