"""AOT compile path: train offline, lower to HLO text, export artifacts.

Runs ONCE at build time (`make artifacts`); python never appears on the
request path.  Produces, under ``artifacts/``:

* ``step_uncond_b{B}.hlo.txt``  — fused sampler step: embed t, score
  (Pallas fused MLP with baked conductances), Euler(-Maruyama) update.
  The rust digital-baseline sampler drives this N times per batch.
* ``step_cond_b{B}.hlo.txt``    — conditional variant with classifier-free
  guidance baked in (two score evaluations + Eq. 7 combine).
* ``score_uncond_b{B}.hlo.txt`` — raw score field (Fig. 3d vector field).
* ``decoder_b{B}.hlo.txt``      — VAE decoder, latent -> 12x12 pixels.
* ``weights_uncond.json`` / ``weights_cond.json`` / ``vae_decoder.json`` —
  weight-space + conductance-space parameters for the rust analog simulator.
* ``meta.json``                 — manifest: artifact IO specs, schedule
  constants, macro constants, class centers, quality-gate stats.

Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects jax>=0.5
serialized protos (64-bit instruction ids); the text parser reassigns ids
(see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import analog, datasets, model, vae
from .kernels import ref
from .kernels.deconv import deconv2d_kernel
from .schedule import DEFAULT as SCHED, EPS_T

BATCHES = (1, 64)
SEED = 7


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    CRITICAL: print with ``print_large_constants=True``.  The default HLO
    printer elides big literals as ``constant({...})`` — and the xla 0.5.1
    text *parser on the rust side silently accepts that as an all-zeros
    constant*, which zeroed out every baked weight matrix until caught by
    the cross-language integration test.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the 0.5.1 parser predates `source_end_line`/`source_end_column`
    # metadata attributes — don't print any metadata
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "constant({...})" not in text and "{...}" not in text, \
        "HLO text contains elided constants — artifact would be corrupt"
    return text


# --- arrays -> json ----------------------------------------------------------

def arr(a) -> dict:
    a = np.asarray(a, np.float32)
    return {"shape": list(a.shape), "data": [float(x) for x in a.reshape(-1)]}


def dump_json(path: str, obj: dict) -> None:
    with open(path, "w") as f:
        json.dump(obj, f)
    print(f"  wrote {path} ({os.path.getsize(path)} bytes)")


# --- jitted deployment functions (lowered per batch size) --------------------

def make_step_uncond(gparams, params):
    """(x, t, dt, mode, noise) -> x_next ; all-analog-equivalent math."""

    def step(x, t, dt, mode, noise):
        b = x.shape[0]
        tb = jnp.full((b,), 0.0) + t
        net = model.score_fwd_analog(gparams, params, x, tb)
        s = model.score_from_net(net, SCHED.sigma(t))
        beta = SCHED.beta(t)
        # state clamp: the integrator output re-enters through the same
        # protective voltage window (see model.sample).
        return (ref.clamp_voltage(ref.euler_step(x, s, beta, dt, noise, mode)),)

    return step


def make_step_cond(gparams, params):
    """(x, t, dt, mode, noise, onehot, lam) -> x_next with CFG (Eq. 7)."""

    def step(x, t, dt, mode, noise, onehot, lam):
        b = x.shape[0]
        tb = jnp.full((b,), 0.0) + t
        n_c = model.score_fwd_analog(gparams, params, x, tb, onehot)
        n_u = model.score_fwd_analog(gparams, params, x, tb,
                                     jnp.zeros_like(onehot))
        net = (1.0 + lam) * n_c - lam * n_u
        s = model.score_from_net(net, SCHED.sigma(t))
        beta = SCHED.beta(t)
        return (ref.clamp_voltage(ref.euler_step(x, s, beta, dt, noise, mode)),)

    return step


def make_score_uncond(gparams, params):
    def fwd(x, t):
        b = x.shape[0]
        tb = jnp.full((b,), 0.0) + t
        return (model.score_fwd_analog(gparams, params, x, tb),)

    return fwd


def make_decoder(dparams):
    """Latent -> pixels through the Pallas deconv kernels (Fig. 2k path)."""
    c1 = dparams["dc1_w"].shape[2]

    def decode(z):
        h = jnp.maximum(z @ dparams["lin_w"] + dparams["lin_b"], 0.0)
        h = h.reshape(-1, 3, 3, c1)
        h = deconv2d_kernel(h, dparams["dc1_w"], dparams["dc1_b"], relu=True)
        h = deconv2d_kernel(h, dparams["dc2_w"], dparams["dc2_b"], tanh=True)
        return (h[..., 0],)

    return decode


def lower_and_write(out_dir, name, fn, specs, manifest):
    lowered = jax.jit(fn).lower(*[jax.ShapeDtypeStruct(s, jnp.float32)
                                  for s in specs])
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest[name] = {"file": f"{name}.hlo.txt",
                      "inputs": [list(s) for s in specs]}
    print(f"  lowered {name}: {len(text)} chars")


# --- quality gates ------------------------------------------------------------

def kl_hist2d(samples: np.ndarray, truth: np.ndarray, bins=24, lim=2.0) -> float:
    """Histogram KL(P_truth || Q_gen) on [-lim, lim]^2 (paper Eq. 8)."""
    edges = np.linspace(-lim, lim, bins + 1)
    p, _, _ = np.histogram2d(truth[:, 0], truth[:, 1], bins=(edges, edges))
    q, _, _ = np.histogram2d(samples[:, 0], samples[:, 1], bins=(edges, edges))
    p = (p + 1e-3) / (p + 1e-3).sum()
    q = (q + 1e-3) / (q + 1e-3).sum()
    return float(np.sum(p * np.log(p / q)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps-uncond", type=int, default=12000)
    ap.add_argument("--steps-cond", type=int, default=14000)
    ap.add_argument("--steps-vae", type=int, default=6000)
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    rng = np.random.default_rng(SEED)
    manifest: dict = {}

    # ---- task 1: unconditional circle (Fig. 3) -----------------------------
    print("== training unconditional score net (circle)")
    circle = datasets.sample_circle(8192, rng)
    p_unc, loss_unc = model.train_score(jax.random.PRNGKey(SEED), circle,
                                        steps=args.steps_uncond)
    g_unc = analog.map_to_conductance(p_unc)
    print(f"  final DSM loss {loss_unc:.4f}  gains {g_unc['gains']}")

    # gate on the *quantized* (deployment-equivalent) weights: this is the
    # function the conductances will realize
    p_unc_q = model.quantize_weights_ste(p_unc)
    gen = np.asarray(model.sample(p_unc_q, jax.random.PRNGKey(1), 2000,
                                  n_steps=200, mode="ode"))
    kl_unc = kl_hist2d(gen, datasets.sample_circle(20000, rng))
    print(f"  quality gate: circle ODE-200 KL (quantized) = {kl_unc:.4f}")

    # ---- task 2: conditional letters via VAE latents (Fig. 4) --------------
    print("== training VAE (synthetic EMNIST letters H/K/U)")
    imgs, labels = datasets.letters_dataset(1024, seed=SEED)
    p_vae, loss_vae = vae.train_vae(jax.random.PRNGKey(SEED + 1), imgs, labels,
                                    steps=args.steps_vae)
    lat = vae.encode_dataset(p_vae, imgs)
    print(f"  VAE loss {loss_vae:.4f}; latent class means:")
    for ci, name in enumerate(datasets.LETTERS):
        m = lat[labels == ci].mean(axis=0)
        print(f"    {name}: ({m[0]:+.3f}, {m[1]:+.3f}) "
              f"target ({datasets.CLASS_CENTERS[ci][0]:+.3f}, "
              f"{datasets.CLASS_CENTERS[ci][1]:+.3f})")

    print("== training conditional score net (latents)")
    p_cond, loss_cond = model.train_score(jax.random.PRNGKey(SEED + 2), lat,
                                          labels, steps=args.steps_cond)
    g_cond = analog.map_to_conductance(p_cond)
    print(f"  final DSM loss {loss_cond:.4f}  gains {g_cond['gains']}")

    oh = jax.nn.one_hot(jnp.full((600,), 0), model.N_CLASSES)
    gen_h = np.asarray(model.sample(p_cond, jax.random.PRNGKey(2), 600,
                                    n_steps=200, mode="ode", onehot=oh,
                                    lam=2.0))
    print(f"  quality gate: class-H latent mean "
          f"({gen_h[:, 0].mean():+.3f}, {gen_h[:, 1].mean():+.3f})")

    # ---- lower artifacts ----------------------------------------------------
    print("== lowering HLO artifacts")
    dparams = {k: jnp.asarray(v) for k, v in vae.decoder_dict(p_vae).items()}
    for b in BATCHES:
        lower_and_write(out, f"step_uncond_b{b}",
                        make_step_uncond(g_unc, p_unc),
                        [(b, 2), (), (), (), (b, 2)], manifest)
        lower_and_write(out, f"step_cond_b{b}",
                        make_step_cond(g_cond, p_cond),
                        [(b, 2), (), (), (), (b, 2), (b, 3), ()], manifest)
        lower_and_write(out, f"score_uncond_b{b}",
                        make_score_uncond(g_unc, p_unc),
                        [(b, 2), ()], manifest)
        lower_and_write(out, f"decoder_b{b}", make_decoder(dparams),
                        [(b, 2)], manifest)

    # ---- weights + meta ------------------------------------------------------
    def score_weights(params, gp):
        return {
            "w1": arr(params.w1), "b1": arr(params.b1),
            "w2": arr(params.w2), "b2": arr(params.b2),
            "w3": arr(params.w3), "b3": arr(params.b3),
            "emb_w": arr(params.emb_w), "cond_proj": arr(params.cond_proj),
            "g1": arr(gp["g1"]), "g2": arr(gp["g2"]), "g3": arr(gp["g3"]),
            "scalars": {"gain1": gp["gains"][0], "gain2": gp["gains"][1],
                        "gain3": gp["gains"][2]},
        }

    dump_json(os.path.join(out, "weights_uncond.json"),
              score_weights(p_unc, g_unc))
    dump_json(os.path.join(out, "weights_cond.json"),
              score_weights(p_cond, g_cond))
    dump_json(os.path.join(out, "vae_decoder.json"),
              {k: arr(v) for k, v in vae.decoder_dict(p_vae).items()})

    meta = {
        "schedule": {"beta_min": SCHED.beta_min, "beta_max": SCHED.beta_max,
                     "t_end": SCHED.t_end, "eps_t": EPS_T},
        "macro": {"v_clamp_lo": ref.V_CLAMP_LO, "v_clamp_hi": ref.V_CLAMP_HI,
                  "g_fixed_ms": ref.G_FIXED_MS,
                  "g_cell_lo_ms": ref.G_CELL_LO_MS,
                  "g_cell_hi_ms": ref.G_CELL_HI_MS,
                  "n_levels": ref.N_LEVELS},
        "model": {"hidden": model.HIDDEN, "dim": model.DIM,
                  "n_classes": model.N_CLASSES},
        "class_centers": [list(map(float, c)) for c in datasets.CLASS_CENTERS],
        # actual (trained) latent class statistics — what conditional
        # generation is evaluated against downstream
        "latent_class_means": [
            [float(v) for v in lat[labels == ci].mean(axis=0)]
            for ci in range(model.N_CLASSES)],
        "latent_class_stds": [
            [float(v) for v in lat[labels == ci].std(axis=0)]
            for ci in range(model.N_CLASSES)],
        "quality": {"kl_uncond_ode200": kl_unc,
                    "dsm_loss_uncond": loss_unc,
                    "dsm_loss_cond": loss_cond, "vae_loss": loss_vae},
        "artifacts": manifest,
        "batches": list(BATCHES),
        "seed": SEED,
    }
    dump_json(os.path.join(out, "meta.json"), meta)
    print("== artifacts complete")


if __name__ == "__main__":
    main()
