"""L2 <-> hardware bridge: weight -> conductance mapping and noise models.

Implements the paper's deployment path (Fig. 3b): offline-trained weights
are mapped onto the macro's programmable conductance window and quantized
to the >= 64 discernible linear states of Fig. 2d.  Also provides the write
and read noise models of Fig. 5 so the python tests can cross-validate the
rust device simulator's noise statistics.

Mapping contract (shared with rust `crossbar::mapper`):

    W = tia_gain * (G_mem - G_FIXED)          # software weight, V/V
    G_mem in [0.02, 0.10] mS, G_FIXED = 0.05 mS
    => W / tia_gain in [-0.03, +0.05] mS

Each layer has its own TIA gain (its own feedback-resistor bank on the
PCB), chosen as the smallest gain that fits that layer's weights into the
window — maximizing the used conductance range per layer and therefore
minimizing the 64-level quantization error.
"""

from __future__ import annotations

import numpy as np

from .kernels import ref
from .model import ScoreParams

W_NEG_MAX = ref.G_FIXED_MS - ref.G_CELL_LO_MS   # 0.03 mS of negative headroom
W_POS_MAX = ref.G_CELL_HI_MS - ref.G_FIXED_MS   # 0.05 mS of positive headroom

# Fig. 5 noise magnitudes (fractions of the conductance window).
WRITE_NOISE_STD_MS = 0.0008   # residual write-verify error, std in mS
READ_NOISE_FRAC = 0.01        # read fluctuation, std = frac * G (Fig. 2e/5c)


def required_gain(weights: list[np.ndarray]) -> float:
    """Smallest shared TIA gain fitting all weights in the conductance window."""
    g = 1e-6
    for w in weights:
        w = np.asarray(w)
        if w.size == 0:
            continue
        g = max(g,
                float(np.max(-w, initial=0.0)) / W_NEG_MAX,
                float(np.max(w, initial=0.0)) / W_POS_MAX)
    return g


def weight_to_conductance(w: np.ndarray, gain: float) -> np.ndarray:
    """W -> G_mem (mS), clipped into the programmable window."""
    g = np.asarray(w, np.float64) / gain + ref.G_FIXED_MS
    return np.clip(g, ref.G_CELL_LO_MS, ref.G_CELL_HI_MS).astype(np.float32)


def quantize(g_mem: np.ndarray, n_levels: int = ref.N_LEVELS) -> np.ndarray:
    """Snap to the macro's n_levels linear conductance states (Fig. 2d)."""
    lo, hi = ref.G_CELL_LO_MS, ref.G_CELL_HI_MS
    step = (hi - lo) / (n_levels - 1)
    return (lo + np.round((np.asarray(g_mem) - lo) / step) * step).astype(np.float32)


def add_write_noise(g_mem: np.ndarray, rng: np.random.Generator,
                    std_ms: float = WRITE_NOISE_STD_MS) -> np.ndarray:
    """Residual error of the write-verify programming loop (Fig. 5b).

    The loop SET/RESETs until conductance lands in a tolerance band around
    target; the landing point within the band is random — modeled as
    truncated Gaussian (2 sigma truncation == the verify band edges).
    """
    n = rng.standard_normal(g_mem.shape)
    n = np.clip(n, -2.0, 2.0)
    g = np.asarray(g_mem) + std_ms * n
    return np.clip(g, ref.G_CELL_LO_MS, ref.G_CELL_HI_MS).astype(np.float32)


def add_read_noise(g_mem: np.ndarray, rng: np.random.Generator,
                   frac: float = READ_NOISE_FRAC) -> np.ndarray:
    """Instantaneous conductance fluctuation (Fig. 2e / 5c): std = frac * G."""
    g = np.asarray(g_mem)
    return (g * (1.0 + frac * rng.standard_normal(g.shape))).astype(np.float32)


def map_to_conductance(params: ScoreParams, n_levels: int = ref.N_LEVELS,
                       write_noise_rng: np.random.Generator | None = None) -> dict:
    """Full deployment mapping of a trained score net.

    Returns dict(g1, g2, g3, b1, b2, b3, gains) — conductances in mS,
    biases unchanged (injected post-TIA as currents), per-layer TIA gains.
    Pass ``write_noise_rng`` to emulate programming error (Fig. 5e/f).
    """
    ws = [np.asarray(params.w1), np.asarray(params.w2), np.asarray(params.w3)]
    gains = [required_gain([w]) for w in ws]
    gs = [quantize(weight_to_conductance(w, g), n_levels)
          for w, g in zip(ws, gains)]
    if write_noise_rng is not None:
        gs = [add_write_noise(g, write_noise_rng) for g in gs]
    return dict(g1=gs[0], g2=gs[1], g3=gs[2],
                b1=np.asarray(params.b1), b2=np.asarray(params.b2),
                b3=np.asarray(params.b3),
                gains=tuple(float(g) for g in gains))


def conductance_to_weight(g_mem: np.ndarray, gain: float) -> np.ndarray:
    """Inverse mapping, used to quantify deployment error in tests."""
    return (gain * (np.asarray(g_mem, np.float64) - ref.G_FIXED_MS)).astype(np.float32)
