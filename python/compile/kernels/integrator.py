"""Pallas kernel: batched reverse-time Euler(-Maruyama) integration step.

The digital baseline of the paper (Fig. 3f / 4g, "state-of-the-art GPU")
discretizes Eq. (1)/(2) into N Euler steps.  This kernel is that step —
the building block the rust coordinator drives N times per sample, letting
the benches sweep N against generation quality.

A single artifact serves both SDE and ODE sampling via a float ``mode``
operand (1.0 -> SDE with the supplied Wiener increment, 0.0 -> probability
flow ODE), so the executable cache in rust holds one program per batch
shape rather than per sampler.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 64


def _kernel(x_ref, s_ref, n_ref, k_ref, o_ref):
    """k_ref packs the scalars [beta_t, dt, mode_sde] (SMEM-style operand)."""
    beta_t = k_ref[0]
    dt = k_ref[1]
    mode = k_ref[2]
    x = x_ref[...]
    score = s_ref[...]
    drift = -0.5 * beta_t * x
    rhs_sde = drift - beta_t * score
    rhs_ode = drift - 0.5 * beta_t * score
    rhs = mode * rhs_sde + (1.0 - mode) * rhs_ode
    diff = mode * jnp.sqrt(jnp.maximum(beta_t * dt, 0.0))
    o_ref[...] = x - dt * rhs + diff * n_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b",))
def euler_step_kernel(x, score, noise, beta_t, dt, mode_sde,
                      block_b: int = BLOCK_B):
    """One reverse-time Euler step; matches :func:`ref.euler_step`.

    Args:
      x:      (batch, d) current state.
      score:  (batch, d) score-network output at (x, t).
      noise:  (batch, d) standard normal increments (ignored when ODE).
      beta_t, dt, mode_sde: scalars (traced, so one lowering serves sweeps).
    """
    b, d = x.shape
    blk = min(block_b, b)
    grid = (pl.cdiv(b, blk),)
    k = jnp.stack([jnp.asarray(beta_t, jnp.float32),
                   jnp.asarray(dt, jnp.float32),
                   jnp.asarray(mode_sde, jnp.float32)])
    tile = pl.BlockSpec((blk, d), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[tile, tile, tile, pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), score.astype(jnp.float32),
      noise.astype(jnp.float32), k)
