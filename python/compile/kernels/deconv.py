"""Pallas kernel: transposed 2-D convolution for the VAE decoder.

The paper's latent-diffusion pipeline (Fig. 4a/c) decodes the 2-D latent
back to pixel space with one linear layer and two deconvolution layers,
realized on resistive-memory arrays (Fig. 2k).  This kernel implements the
deconvolution as the zero-insertion-upsample + flipped-kernel correlation
identity, fused per batch tile.  Feature maps here are tiny (<= 12x12x32,
~18 KB) so the whole tile set is VMEM-resident; the grid runs over batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 64


def _kernel(x_ref, w_ref, b_ref, o_ref, *, stride: int, pad: int,
            relu: bool, tanh: bool):
    x = x_ref[...]
    w = w_ref[...]
    n, ih, iw, ci = x.shape
    kh, kw, _, co = w.shape
    oh, ow = ih * stride, iw * stride

    up = jnp.zeros((n, ih * stride, iw * stride, ci), x.dtype)
    up = up.at[:, ::stride, ::stride, :].set(x)
    plo = kh - 1 - pad
    phi_h = oh + pad - (ih - 1) * stride - 1
    phi_w = ow + pad - (iw - 1) * stride - 1
    up = jnp.pad(up, ((0, 0), (plo, phi_h), (plo, phi_w), (0, 0)))
    wf = w[::-1, ::-1, :, :]

    out = jnp.zeros((n, oh, ow, co), x.dtype)
    for ky in range(kh):       # static: unrolled into 16 fused MACs
        for kx in range(kw):
            patch = up[:, ky:ky + oh, kx:kx + ow, :]
            out = out + jnp.einsum("nhwc,cf->nhwf", patch, wf[ky, kx])
    out = out + b_ref[...]
    if relu:
        out = jnp.maximum(out, 0.0)
    if tanh:
        out = jnp.tanh(out)
    o_ref[...] = out


@functools.partial(jax.jit,
                   static_argnames=("stride", "pad", "relu", "tanh", "block_b"))
def deconv2d_kernel(x, w, b, stride: int = 2, pad: int = 1,
                    relu: bool = False, tanh: bool = False,
                    block_b: int = BLOCK_B):
    """Transposed conv; matches :func:`ref.deconv2d` (+ optional epilogue).

    Args:
      x: (batch, h, w, ci) NHWC feature map.
      w: (kh, kw, ci, co) HWIO taps.
      b: (co,) bias.
    Returns: (batch, h*stride, w*stride, co).
    """
    n, ih, iw, ci = x.shape
    kh, kw, _, co = w.shape
    oh, ow = ih * stride, iw * stride
    blk = min(block_b, n)
    grid = (pl.cdiv(n, blk),)
    return pl.pallas_call(
        functools.partial(_kernel, stride=stride, pad=pad,
                          relu=bool(relu), tanh=bool(tanh)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, ih, iw, ci), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ci, co), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((co,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, oh, ow, co), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, co), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32))
