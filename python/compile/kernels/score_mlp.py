"""Pallas kernel: fused 3-layer analog score network forward pass.

The paper's score function s_theta(x, t) is a 2 -> H -> H -> 2 fully
connected network realized on three crossbar arrays with the time (and
condition) embedding injected as bias *currents* into both hidden layers
(Fig. 2i, Fig. 4b).  This kernel fuses all three MVMs, both embedding
injections, the bias adds and the diode-clamp ReLUs into a single VMEM-
resident pass: the entire weight set is < 1 KB, so everything lives in
VMEM and the grid runs over batch tiles only — the TPU analogue of the
macro holding all conductances while voltages stream through.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_B = 64


def _kernel(x_ref, emb_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
            o_ref, *, tia_gain: tuple):
    x = jnp.clip(x_ref[...], ref.V_CLAMP_LO, ref.V_CLAMP_HI)
    emb = emb_ref[...]

    # Layer 1: crossbar MVM + TIA, embedding injected as bias current.
    # Per-layer TIA gains: each layer has its own feedback-resistor bank,
    # letting the mapper use the full conductance window per layer.
    h = jnp.dot(x, w1_ref[...] - ref.G_FIXED_MS,
                preferred_element_type=jnp.float32) * tia_gain[0]
    h = jnp.maximum(h + b1_ref[...] + emb, 0.0)
    h = jnp.clip(h, ref.V_CLAMP_LO, ref.V_CLAMP_HI)

    # Layer 2.
    h = jnp.dot(h, w2_ref[...] - ref.G_FIXED_MS,
                preferred_element_type=jnp.float32) * tia_gain[1]
    h = jnp.maximum(h + b2_ref[...] + emb, 0.0)
    h = jnp.clip(h, ref.V_CLAMP_LO, ref.V_CLAMP_HI)

    # Output layer: linear (no activation).
    o = jnp.dot(h, w3_ref[...] - ref.G_FIXED_MS,
                preferred_element_type=jnp.float32) * tia_gain[2]
    o_ref[...] = o + b3_ref[...]


@functools.partial(jax.jit, static_argnames=("tia_gain", "block_b"))
def score_mlp_kernel(x, emb, w1, b1, w2, b2, w3, b3,
                     tia_gain: float | tuple = 1.0, block_b: int = BLOCK_B):
    """Fused score-network forward; matches :func:`ref.score_mlp`.

    Note the hidden activations pass through the protective clamp before
    feeding the next crossbar, exactly as on the PCB (each layer's input is
    a physical BL voltage).  The reference oracle applies the same clamp
    inside :func:`ref.crossbar_mvm`.

    Args:
      x:   (batch, d_in) state voltages.
      emb: (batch, H) summed time(+condition) embedding.
      w*:  conductance-space weights (mS), b*: bias voltages.
      tia_gain: single gain or per-layer (g1, g2, g3) tuple.
    Returns: (batch, d_out) score estimate.
    """
    if not isinstance(tia_gain, tuple):
        tia_gain = (float(tia_gain),) * 3
    tia_gain = tuple(float(g) for g in tia_gain)
    b, d_in = x.shape
    hdim = w1.shape[1]
    d_out = w3.shape[1]
    blk = min(block_b, b)
    grid = (pl.cdiv(b, blk),)
    full = lambda *shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        functools.partial(_kernel, tia_gain=tia_gain),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d_in), lambda i: (i, 0)),
            pl.BlockSpec((blk, hdim), lambda i: (i, 0)),
            full(d_in, hdim), full(hdim),
            full(hdim, hdim), full(hdim),
            full(hdim, d_out), full(d_out),
        ],
        out_specs=pl.BlockSpec((blk, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d_out), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), emb.astype(jnp.float32),
      w1.astype(jnp.float32), b1.astype(jnp.float32),
      w2.astype(jnp.float32), b2.astype(jnp.float32),
      w3.astype(jnp.float32), b3.astype(jnp.float32))
