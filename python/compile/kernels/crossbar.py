"""Pallas kernel: analog resistive-memory crossbar MVM.

Hardware adaptation (DESIGN.md §2): the paper performs the MVM with Ohm's
law + Kirchhoff's current law on a 32x32 1T1R macro.  On the TPU-flavored
stack the analogous structure is a VMEM-resident weight tile and a batch-
tiled grid: the conductance matrix plays the role of the physical array
(stays resident, like the programmed cells), while input-voltage batches
stream through — exactly the HBM->VMEM schedule BlockSpec expresses.

The kernel fuses the macro's protective voltage clamp, the shared-negative-
weight subtraction (G_mem - G_fixed), the TIA gain, and optionally the
diode-clamp ReLU epilogue — one pass over the data, no intermediate
materialization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Batch tile: sublane-friendly (multiples of 8); tiny weight tiles mean the
# grid is purely over the batch dimension.
BLOCK_B = 64


def _kernel(v_ref, g_ref, o_ref, *, tia_gain: float, relu: bool):
    """One batch-tile of the crossbar MVM (all operands VMEM-resident)."""
    v = jnp.clip(v_ref[...], ref.V_CLAMP_LO, ref.V_CLAMP_HI)
    w = g_ref[...] - ref.G_FIXED_MS
    acc = jnp.dot(v, w, preferred_element_type=jnp.float32) * tia_gain
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tia_gain", "relu", "block_b"))
def crossbar_mvm_kernel(v, g_mem, tia_gain: float = 1.0, relu: bool = False,
                        block_b: int = BLOCK_B):
    """Batched analog crossbar MVM; matches :func:`ref.crossbar_mvm`.

    Args:
      v:     (batch, n_in) input voltages (software units; 0.1 V == 1).
      g_mem: (n_in, n_out) programmed conductances in mS.
    Returns: (batch, n_out) TIA output voltages.
    """
    b, n_in = v.shape
    n_out = g_mem.shape[1]
    blk = min(block_b, b)
    grid = (pl.cdiv(b, blk),)
    return pl.pallas_call(
        functools.partial(_kernel, tia_gain=float(tia_gain), relu=bool(relu)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_in, n_out), lambda i: (0, 0)),  # weights resident
        ],
        out_specs=pl.BlockSpec((blk, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_out), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(v.astype(jnp.float32), g_mem.astype(jnp.float32))
