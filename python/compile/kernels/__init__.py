"""L1: Pallas kernels for the analog in-memory compute hot-spots.

Every kernel is authored with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls; see /opt/xla-example/README.md) and has a pure-jnp
oracle in :mod:`ref` checked by pytest + hypothesis.
"""

from . import ref  # noqa: F401
from .crossbar import crossbar_mvm_kernel  # noqa: F401
from .score_mlp import score_mlp_kernel  # noqa: F401
from .integrator import euler_step_kernel  # noqa: F401
from .deconv import deconv2d_kernel  # noqa: F401
