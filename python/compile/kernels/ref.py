"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: the pytest suite sweeps shapes and
seeds (hypothesis) and asserts the Pallas kernels (interpret=True) match
these implementations to float32 tolerance.  They are also the *semantic*
contract mirrored by the rust analog simulator (`rust/src/crossbar`,
`rust/src/vae`), so the three implementations — ref, kernel, rust — are
mutually checkable.

Voltage convention (paper Fig. 3): 0.1 V is the software unit 1.0; input
voltages are clamped to the macro's safe window [-0.2 V, 0.4 V], i.e.
[-2, 4] in software units.
"""

from __future__ import annotations

import jax.numpy as jnp

# Macro constants (paper Fig. 2 / Methods) ----------------------------------
V_CLAMP_LO = -2.0          # -0.2 V in software units (0.1 V == 1.0)
V_CLAMP_HI = 4.0           # +0.4 V
G_FIXED_MS = 0.05          # shared 20 kOhm negative-weight conductance, in mS
G_CELL_LO_MS = 0.02        # programmable cell window, in mS
G_CELL_HI_MS = 0.10
N_LEVELS = 64              # >=64 discernible linear conductance states


def clamp_voltage(v):
    """Protective input clamp of the macro (Supplementary Fig. 2)."""
    return jnp.clip(v, V_CLAMP_LO, V_CLAMP_HI)


def crossbar_mvm(v, g_mem, tia_gain=1.0, relu=False):
    """Analog crossbar matrix-vector multiply, differential-pair weights.

    Args:
      v:      (batch, n_in) input voltages, software units.
      g_mem:  (n_in, n_out) programmed cell conductances in mS.
      tia_gain: transimpedance gain folded with the 0.1 V unit so the output
        is back in software units.
      relu:   apply the diode-clamp ReLU epilogue.

    The effective weight of a column pair is ``G_mem - G_fixed`` (the paper's
    row-shared negative weight saves 50% of the cells).  Ohm's law gives the
    per-cell current, Kirchhoff's current law the column sum.
    """
    vc = clamp_voltage(v)
    w = g_mem - G_FIXED_MS
    out = tia_gain * (vc @ w)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def time_embedding(t, w):
    """Sinusoidal time embedding, paper Eq. 9: [sin(2 pi W t), cos(2 pi W t)].

    Args:
      t: (batch,) times in [0, T].
      w: (d/2,) fixed random frequency vector.
    Returns: (batch, d) embedding.
    """
    ang = 2.0 * jnp.pi * t[:, None] * w[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def score_mlp(x, emb, params, tia_gain=1.0):
    """Fused 3-layer analog score network: 2 -> H -> H -> 2.

    ``emb`` (batch, H) is the summed time(+condition) embedding injected as
    extra bias current into *both* hidden layers (paper Fig. 2i / Fig. 4b).

    ``params`` is ``dict(w1, b1, w2, b2, w3, b3)`` holding *conductance-space*
    weights in mS (cell values; the G_fixed subtraction happens here, exactly
    as in the macro).
    """
    h1 = crossbar_mvm(x, params["w1"], tia_gain)
    h1 = jnp.maximum(h1 + params["b1"] + emb, 0.0)
    h2 = crossbar_mvm(h1, params["w2"], tia_gain)
    h2 = jnp.maximum(h2 + params["b2"] + emb, 0.0)
    out = crossbar_mvm(h2, params["w3"], tia_gain)
    return out + params["b3"]


def euler_step(x, score, beta_t, dt, noise, mode_sde):
    """One reverse-time Euler(-Maruyama) step of paper Eq. (1)/(2).

    Integrating from t=T down to 0 with positive step ``dt``:

      SDE: x' = x - dt * (f(x,t) - beta * score) + sqrt(beta*dt) * noise
      ODE: x' = x - dt * (f(x,t) - beta/2 * score)

    with f(x,t) = -beta/2 * x (paper Eq. 4) and g^2 = beta (Eq. 5).
    ``mode_sde`` is 1.0 for SDE, 0.0 for ODE — kept as a float so a single
    lowered artifact serves both samplers.
    """
    drift = -0.5 * beta_t * x
    g2 = beta_t
    rhs_sde = drift - g2 * score
    rhs_ode = drift - 0.5 * g2 * score
    rhs = mode_sde * rhs_sde + (1.0 - mode_sde) * rhs_ode
    diff = mode_sde * jnp.sqrt(jnp.maximum(beta_t * dt, 0.0))
    return x - dt * rhs + diff * noise


def deconv2d(x, w, b, stride=2, pad=1):
    """Transposed 2-D convolution, NHWC/HWIO, the VAE decoder building block.

    out[n, oy, ox, co] = b[co] +
        sum_{ky,kx,ci} x[n, iy, ix, ci] * w[ky, kx, ci, co]
        where oy = iy*stride + ky - pad, ox likewise.

    Output side = in_side * stride for kernel 4 / stride 2 / pad 1.
    Implemented as zero-insertion upsampling followed by a direct correlation
    with the *flipped* kernel — the standard transposed-conv identity — in
    pure jnp, so it lowers cleanly and matches the rust implementation
    loop-for-loop.
    """
    n, ih, iw, ci = x.shape
    kh, kw, ci2, co = w.shape
    assert ci == ci2, (ci, ci2)
    oh, ow = ih * stride, iw * stride

    # zero-insert upsample
    up = jnp.zeros((n, ih * stride, iw * stride, ci), x.dtype)
    up = up.at[:, ::stride, ::stride, :].set(x)
    # pad so that a VALID correlation with the flipped kernel yields the
    # transposed-conv output.
    plo = kh - 1 - pad
    phi_h = oh + pad - (ih - 1) * stride - 1
    phi_w = ow + pad - (iw - 1) * stride - 1
    up = jnp.pad(up, ((0, 0), (plo, phi_h), (plo, phi_w), (0, 0)))
    wf = w[::-1, ::-1, :, :]  # flip taps

    out = jnp.zeros((n, oh, ow, co), x.dtype)
    for ky in range(kh):
        for kx in range(kw):
            patch = up[:, ky:ky + oh, kx:kx + ow, :]
            out = out + jnp.einsum("nhwc,cf->nhwf", patch, wf[ky, kx])
    return out + b


def vae_decoder(z, params):
    """Full VAE decoder: linear(2 -> 3*3*C) -> reshape -> deconv -> relu -> deconv -> tanh.

    ``params``: dict with lin_w (2, 9C), lin_b, dc1_w (4,4,C,C2), dc1_b,
    dc2_w (4,4,C2,1), dc2_b.  Output (batch, 12, 12) in [-1, 1].
    """
    c = params["dc1_w"].shape[2]
    h = z @ params["lin_w"] + params["lin_b"]
    h = jnp.maximum(h, 0.0)
    h = h.reshape(-1, 3, 3, c)
    h = deconv2d(h, params["dc1_w"], params["dc1_b"])
    h = jnp.maximum(h, 0.0)
    h = deconv2d(h, params["dc2_w"], params["dc2_b"])
    return jnp.tanh(h[..., 0])
