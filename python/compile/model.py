"""L2: the paper's score network — training-time (weight space) and
deployment-time (conductance space, Pallas kernels) forward passes.

Two parameterizations of the *same* function:

* **weight space** — unconstrained software weights ``W``; used for offline
  training (paper: "weights of the analog neural network are optimized
  offline before being deployed on resistive memory").  Differentiable pure
  jnp, includes the hardware voltage clamps so the trained network is
  faithful to what the macro can realize.
* **conductance space** — after :mod:`analog` maps ``W -> (G_mem, tia_gain)``
  the deployment forward calls the fused Pallas kernel
  (:func:`kernels.score_mlp_kernel`); this is what gets AOT-lowered into the
  HLO artifacts the rust runtime executes.

Equivalence contract: ``W = tia_gain * (G_mem - G_FIXED)`` makes the two
paths agree exactly (up to 64-level conductance quantization), which pytest
asserts.
"""

from __future__ import annotations

import functools

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.score_mlp import score_mlp_kernel
from .schedule import EPS_T, VpSchedule, DEFAULT as DEFAULT_SCHED

HIDDEN = 14        # paper: each hidden layer contains 14 nodes with bias
DIM = 2            # data/latent dimension
N_CLASSES = 3      # letters H, K, U
COND_DROP = 0.1    # classifier-free guidance: condition dropout rate


class ScoreParams(NamedTuple):
    """Weight-space parameters of the 3-layer score MLP (+ fixed encoders)."""

    w1: jax.Array   # (DIM, HIDDEN)
    b1: jax.Array   # (HIDDEN,)
    w2: jax.Array   # (HIDDEN, HIDDEN)
    b2: jax.Array   # (HIDDEN,)
    w3: jax.Array   # (HIDDEN, DIM)
    b3: jax.Array   # (DIM,)
    emb_w: jax.Array   # (HIDDEN//2,) fixed random frequencies (Eq. 9)
    cond_proj: jax.Array  # (N_CLASSES, HIDDEN) fixed random projection (Fig. 4b)


def init_params(key, hidden: int = HIDDEN, dim: int = DIM,
                n_classes: int = N_CLASSES) -> ScoreParams:
    """He-style init for the trainables; fixed Gaussian encoders."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    he = lambda k, fi, fo: jax.random.normal(k, (fi, fo)) * jnp.sqrt(2.0 / fi)
    return ScoreParams(
        w1=he(k1, dim, hidden), b1=jnp.zeros(hidden),
        w2=he(k2, hidden, hidden), b2=jnp.zeros(hidden),
        w3=he(k3, hidden, dim), b3=jnp.zeros(dim),
        emb_w=jax.random.normal(k4, (hidden // 2,)),
        cond_proj=jax.random.normal(k5, (n_classes, hidden)) * 0.5,
    )


def make_embedding(params: ScoreParams, t, onehot=None):
    """Summed time(+condition) embedding injected into both hidden layers.

    ``onehot`` (batch, N_CLASSES) may contain all-zero rows — those receive
    the unconditional embedding (classifier-free guidance's null token).
    """
    emb = ref.time_embedding(t, params.emb_w)
    if onehot is not None:
        emb = emb + onehot @ params.cond_proj
    return emb


def score_fwd(params: ScoreParams, x, t, onehot=None):
    """Weight-space forward with the macro's voltage clamps (training path).

    Matches the hardware semantics: input and hidden-layer voltages are
    clipped to [-2, 4] software units (the [-0.2 V, 0.4 V] protective window)
    before driving the next crossbar.
    """
    emb = make_embedding(params, t, onehot)
    h = ref.clamp_voltage(x)
    h = jnp.maximum(h @ params.w1 + params.b1 + emb, 0.0)
    h = ref.clamp_voltage(h)
    h = jnp.maximum(h @ params.w2 + params.b2 + emb, 0.0)
    h = ref.clamp_voltage(h)
    return h @ params.w3 + params.b3


def score_fwd_analog(gparams: dict, params: ScoreParams, x, t, onehot=None):
    """Conductance-space forward via the fused Pallas kernel (deployment path).

    ``gparams`` comes from :func:`analog.map_to_conductance`:
    ``dict(g1, g2, g3, b1, b2, b3, gains)`` with per-layer TIA gains (one
    feedback-resistor bank per layer on the PCB).
    """
    emb = make_embedding(params, t, onehot)
    return score_mlp_kernel(x, emb, gparams["g1"], gparams["b1"],
                            gparams["g2"], gparams["b2"],
                            gparams["g3"], gparams["b3"],
                            tia_gain=tuple(gparams["gains"]))


def cfg_score(params: ScoreParams, x, t, onehot, lam):
    """Classifier-free guidance, paper Eq. 7: (1+lam) s(x,c,t) - lam s(x,t).

    Applied in network (epsilon) space; since score = -net/sigma is linear
    in net, guiding either space is equivalent.
    """
    s_cond = score_fwd(params, x, t, onehot)
    s_unc = score_fwd(params, x, t, jnp.zeros_like(onehot))
    return (1.0 + lam) * s_cond - lam * s_unc


def score_from_net(net_out, sigma_t):
    """Epsilon-parameterization: score = -net(x, t) / sigma(t).

    The 1/sigma rescale is folded into the predetermined ``g^2(t)/sigma(t)``
    multiplier waveform on hardware (see schedule.py docstring).
    """
    return -net_out / sigma_t


def quantize_weights_ste(params: ScoreParams) -> ScoreParams:
    """Hardware-aware quantization with a straight-through estimator.

    Each weight matrix is mapped through the deployment pipeline — per-layer
    TIA gain, conductance window, 64 linear levels — and back, exactly as
    :mod:`analog` will do at export; gradients pass through unchanged (STE).
    Training the final stretch with this in the loss is what makes the
    *deployed* (conductance-space) network match the trained one: without it
    the 64-level snap of large trained weights costs ~0.5 output error on a
    O(1) signal.
    """
    from .kernels.ref import G_CELL_HI_MS, G_CELL_LO_MS, G_FIXED_MS, N_LEVELS

    def q(w):
        neg_max = G_FIXED_MS - G_CELL_LO_MS
        pos_max = G_CELL_HI_MS - G_FIXED_MS
        gain = jnp.maximum(jnp.max(jnp.maximum(w, 0.0)) / pos_max,
                           jnp.max(jnp.maximum(-w, 0.0)) / neg_max)
        gain = jax.lax.stop_gradient(jnp.maximum(gain, 1e-6))
        g = jnp.clip(w / gain + G_FIXED_MS, G_CELL_LO_MS, G_CELL_HI_MS)
        step = (G_CELL_HI_MS - G_CELL_LO_MS) / (N_LEVELS - 1)
        gq = G_CELL_LO_MS + jnp.round((g - G_CELL_LO_MS) / step) * step
        wq = gain * (gq - G_FIXED_MS)
        return w + jax.lax.stop_gradient(wq - w)

    return params._replace(w1=q(params.w1), w2=q(params.w2), w3=q(params.w3))


# --- denoising score matching training --------------------------------------

def dsm_loss(params: ScoreParams, key, x0, onehot=None,
             sched: VpSchedule = DEFAULT_SCHED, cond_drop: float = COND_DROP,
             t_power: float = 1.6, qat: bool = False):
    """Denoising score-matching loss, epsilon-parameterized.

    x_t = alpha(t) x0 + sigma(t) eps; the network predicts eps, so
    loss = E || net(x_t, t) - eps ||^2 — the standard DDPM objective,
    equivalent to sigma^2-weighted score matching.  The net output stays
    O(1), which is what the voltage-clamped analog MLP can represent.
    With conditions, each sample's label is dropped with prob ``cond_drop``
    (the CFG null token) so one network learns both scores.

    ``t_power`` > 1 oversamples small t (t = eps + (T-eps) u^power): the
    14-unit analog net is capacity-bound and the small-t score shapes the
    final sharpness of the generated distribution.
    """
    kt, ke, kd = jax.random.split(key, 3)
    n = x0.shape[0]
    u = jax.random.uniform(kt, (n,))
    t = EPS_T + (sched.t_end - EPS_T) * u ** t_power
    eps = jax.random.normal(ke, x0.shape)
    alpha = sched.alpha(t)[:, None]
    sigma = sched.sigma(t)[:, None]
    xt = alpha * x0 + sigma * eps
    if onehot is not None:
        keep = (jax.random.uniform(kd, (n, 1)) > cond_drop).astype(x0.dtype)
        onehot = onehot * keep
    fwd_params = quantize_weights_ste(params) if qat else params
    net = score_fwd(fwd_params, xt, t, onehot)
    return jnp.mean(jnp.sum((net - eps) ** 2, axis=-1))


# --- minimal Adam (no optax in the offline image) ----------------------------

class AdamState(NamedTuple):
    step: jax.Array
    mu: ScoreParams
    nu: ScoreParams


def adam_init(params) -> AdamState:
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(jnp.zeros((), jnp.int32), z, z)


def adam_update(grads, state: AdamState, params, lr=1e-3, b1=0.9, b2=0.999,
                eps=1e-8):
    step = state.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state.nu, grads)
    mhat = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** step), mu)
    vhat = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** step), nu)
    new = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mhat, vhat)
    return new, AdamState(step, mu, nu)


def train_score(key, data: np.ndarray, labels: np.ndarray | None = None,
                steps: int = 12000, batch: int = 1024, lr: float = 3e-3,
                sched: VpSchedule = DEFAULT_SCHED,
                freeze_encoders: bool = False, qat_frac: float = 0.15,
                weight_clip: float | None = 1.2):
    """Offline training loop (the paper optimizes weights offline, Fig. 3b).

    Cosine learning-rate decay (to 10% of ``lr``) and small-t oversampling
    — both needed to squeeze the paper's 14-hidden-unit budget.  The time/
    condition encoders stay sinusoidal / linear-projection shaped; their
    frequencies and projection are trained unless ``freeze_encoders`` (on
    the PCB they become the pre-programmed DAC waveforms either way).

    Two hardware-deployment measures (ablated in EXPERIMENTS.md):

    * ``weight_clip`` — weights are projected into ±clip after every update,
      bounding the per-layer TIA gain and therefore the 64-level
      quantization step (smaller gain ⇒ finer effective weight grid).
    * ``qat_frac`` — the final fraction of steps run **quantization-aware**:
      the forward pass applies the full deployment mapping (per-layer gain,
      64 conductance levels) with straight-through gradients, so the
      exported conductances reproduce the trained function
      (:func:`quantize_weights_ste`).

    Returns (trained :class:`ScoreParams`, final loss).
    """
    kinit, kloop = jax.random.split(key)
    params = init_params(kinit)
    state = adam_init(params)
    data = jnp.asarray(data, jnp.float32)
    onehot_all = (None if labels is None
                  else jax.nn.one_hot(jnp.asarray(labels), N_CLASSES))

    @functools.partial(jax.jit, static_argnames=("qat",))
    def step_fn(params, state, key, lr_t, qat):
        kb, kl = jax.random.split(key)
        idx = jax.random.randint(kb, (batch,), 0, data.shape[0])
        x0 = data[idx]
        oh = None if onehot_all is None else onehot_all[idx]
        loss, grads = jax.value_and_grad(dsm_loss)(params, kl, x0, oh,
                                                   sched=sched, qat=qat)
        if freeze_encoders:
            grads = grads._replace(emb_w=jnp.zeros_like(grads.emb_w),
                                   cond_proj=jnp.zeros_like(grads.cond_proj))
        params, state = adam_update(grads, state, params, lr=lr_t)
        if weight_clip is not None:
            c = weight_clip
            params = params._replace(w1=jnp.clip(params.w1, -c, c),
                                     w2=jnp.clip(params.w2, -c, c),
                                     w3=jnp.clip(params.w3, -c, c))
        return params, state, loss

    qat_start = int(steps * (1.0 - qat_frac))
    keys = jax.random.split(kloop, steps)
    loss = jnp.inf
    for i in range(steps):
        lr_t = lr * (0.9 * 0.5 * (1.0 + np.cos(np.pi * i / steps)) + 0.1)
        params, state, loss = step_fn(params, state, keys[i], lr_t,
                                      i >= qat_start)
    return params, float(loss)


# --- reference sampler (python-side quality gate) ----------------------------

def sample(params: ScoreParams, key, n: int, n_steps: int = 200,
           mode: str = "ode", onehot=None, lam: float = 0.0,
           sched: VpSchedule = DEFAULT_SCHED):
    """Discrete reverse-time sampler used to gate training quality at build
    time; the production samplers live in rust.  Returns (n, DIM)."""
    kx, kn = jax.random.split(key)
    x = jax.random.normal(kx, (n, DIM))
    ts = jnp.linspace(sched.t_end, EPS_T, n_steps + 1)
    noises = jax.random.normal(kn, (n_steps, n, DIM))

    def body(x, inp):
        t0, t1, z = inp
        dt = t0 - t1
        tb = jnp.full((n,), t0)
        if onehot is not None:
            net = cfg_score(params, x, tb, onehot, lam)
        else:
            net = score_fwd(params, x, tb)
        s = score_from_net(net, sched.sigma(t0))
        beta = sched.beta(t0)
        x = ref.euler_step(x, s, beta, dt, z, 1.0 if mode == "sde" else 0.0)
        # The macro's protective clamp also bounds the *state* voltages (the
        # integrator output drives the BLs through the same window): this is
        # what keeps far-tail trajectories from running away, on hardware
        # and in every sampler here.
        x = ref.clamp_voltage(x)
        return x, None

    x, _ = jax.lax.scan(body, x, (ts[:-1], ts[1:], noises))
    return x
