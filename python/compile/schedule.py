"""Variance-preserving (VP) SDE schedule for score-based diffusion.

The paper (Methods, "Variance preserving score-based diffusion models") uses
a linearly increasing ``beta(t)`` and the drift/diffusion pair

    f(x, t) = -1/2 * beta(t) * x          (Eq. 4)
    g(t)    = sqrt(beta(t))               (Eq. 5)

so the forward SDE is ``dx = f dt + g dw`` and the reverse-time generative
SDE / probability-flow ODE are Eq. (1) / Eq. (2) of the paper.

**Deviation from the paper (documented, see DESIGN.md §Deviations):** the
paper quotes beta rising 0.001 -> 0.5 over t in [0, T=1].  That integrates
to only 0.25, i.e. alpha(T) = 0.88 — the forward process barely perturbs
the data, so the generative pass started from N(0, I) carries an
irreducible prior-mismatch error (we measured histogram-KL ~0.9 on the
circle task with the quoted range).  We use the same *linear* shape with
``beta_max = 12`` (alpha(T) ~ 0.05, sigma(T) ~ 0.999), which makes the
terminal marginal genuinely Gaussian and reproduces the paper's reported
generation quality.  The quoted range remains available for ablation
(``VpSchedule(beta_max=0.5)``; bench fig5 sweeps exercise it).

The score network is **epsilon-parameterized**: the net outputs
``v = -sigma(t) * score`` (bounded O(1) — exactly what a voltage-clamped
analog MLP can represent), and the ``1/sigma(t)`` rescale is folded into
the *predetermined analog signal* ``g^2(t)`` that the paper's AD633
multipliers already apply in the feedback integrator ("both f(t) and
g^2(t) are crafted as predetermined analog signals", Methods).  Same
circuit, different pre-programmed waveform — hardware-faithful.

All functions are plain ``jnp`` so they can be traced into the AOT-lowered
step functions as constants or scalar inputs.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

# Defaults (paper-shaped linear schedule; strength per §Deviations above) ----
BETA_MIN = 0.001  # beta(0)
BETA_MAX = 12.0   # beta(T); paper quotes 0.5 — see module docstring
T_END = 1.0       # algorithmic horizon T; hardware maps it to a 1 s solve
EPS_T = 0.01      # smallest t used in training/sampling (avoids sigma -> 0)


@dataclasses.dataclass(frozen=True)
class VpSchedule:
    """Variance-preserving schedule ``beta(t) = beta_min + (beta_max - beta_min) t / T``."""

    beta_min: float = BETA_MIN
    beta_max: float = BETA_MAX
    t_end: float = T_END

    def beta(self, t):
        """Instantaneous noise rate ``beta(t)``."""
        return self.beta_min + (self.beta_max - self.beta_min) * (t / self.t_end)

    def int_beta(self, t):
        """``\\int_0^t beta(s) ds`` — closed form for the linear schedule."""
        return self.beta_min * t + 0.5 * (self.beta_max - self.beta_min) * t**2 / self.t_end

    def alpha(self, t):
        """Signal retention ``alpha(t) = exp(-1/2 \\int beta)`` of the VP forward process."""
        return jnp.exp(-0.5 * self.int_beta(t))

    def sigma(self, t):
        """Perturbation std ``sigma(t) = sqrt(1 - alpha(t)^2)``."""
        return jnp.sqrt(jnp.maximum(1.0 - self.alpha(t) ** 2, 1e-12))

    def drift(self, x, t):
        """Forward drift ``f(x, t) = -1/2 beta(t) x`` (paper Eq. 4)."""
        return -0.5 * self.beta(t) * x

    def diffusion(self, t):
        """Diffusion coefficient ``g(t) = sqrt(beta(t))`` (paper Eq. 5)."""
        return jnp.sqrt(self.beta(t))

    def reverse_sde_rhs(self, x, t, score):
        """Reverse-time SDE differential term ``F_SDE`` (paper Eq. 1), noise excluded.

        ``dx = [f(x,t) - g(t)^2 * score] dt + g(t) dw`` integrated from T down
        to 0.  The Wiener increment is supplied by the caller (hardware: the
        intrinsic read noise of the macro; digital baseline: a PRNG).
        """
        return self.drift(x, t) - self.beta(t) * score

    def reverse_ode_rhs(self, x, t, score):
        """Probability-flow ODE differential term ``F_ODE`` (paper Eq. 2)."""
        return self.drift(x, t) - 0.5 * self.beta(t) * score

    def g2_over_sigma(self, t):
        """The predetermined multiplier waveform ``g^2(t) / sigma(t)``.

        With the epsilon-parameterized network (net = -sigma * score), the
        reverse dynamics use ``g^2 * score = -(g^2/sigma) * net``; this is
        the analog signal the AD633 multipliers receive instead of plain
        ``g^2(t)`` (see module docstring).
        """
        return self.beta(t) / self.sigma(t)


DEFAULT = VpSchedule()
