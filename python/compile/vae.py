"""L2: the outer VAE of the latent-diffusion task (paper Fig. 4a).

Encoder (build-time only, never deployed): MLP 144 -> 64 -> (mu, logvar),
latent dim 2.  Decoder (deployed on resistive memory, Fig. 2k): one linear
layer + two deconvolution layers, exactly the paper's topology; its forward
is mirrored by :func:`kernels.ref.vae_decoder` and the Pallas
:func:`kernels.deconv.deconv2d_kernel` for the AOT artifact.

Training loss is paper Eq. 10: reconstruction MSE plus a KL that pins each
class's latent posterior to a *preset center* ``mu_hat_i`` — that is what
makes the three conditional distributions of Fig. 4d separable clusters.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .datasets import CLASS_CENTERS, IMG
from .model import AdamState, adam_init, adam_update

LATENT = 2
ENC_HIDDEN = 64
DEC_C1 = 8   # channels after the linear layer / input of deconv1
DEC_C2 = 8   # channels between deconv1 and deconv2
KL_GAMMA = 0.4  # Eq. 10's gamma balancing MSE vs KL (strong enough to pin
                # each class's latent cluster to its preset center)


class VaeParams(NamedTuple):
    # encoder
    e_w1: jax.Array  # (144, ENC_HIDDEN)
    e_b1: jax.Array
    e_wmu: jax.Array  # (ENC_HIDDEN, LATENT)
    e_bmu: jax.Array
    e_wlv: jax.Array  # (ENC_HIDDEN, LATENT)
    e_blv: jax.Array
    # decoder (deployed)
    lin_w: jax.Array  # (LATENT, 3*3*DEC_C1)
    lin_b: jax.Array
    dc1_w: jax.Array  # (4, 4, DEC_C1, DEC_C2)
    dc1_b: jax.Array
    dc2_w: jax.Array  # (4, 4, DEC_C2, 1)
    dc2_b: jax.Array


def init_vae(key) -> VaeParams:
    ks = jax.random.split(key, 6)
    he = lambda k, *s: jax.random.normal(k, s) * jnp.sqrt(2.0 / s[0])
    npix = IMG * IMG
    return VaeParams(
        e_w1=he(ks[0], npix, ENC_HIDDEN), e_b1=jnp.zeros(ENC_HIDDEN),
        e_wmu=he(ks[1], ENC_HIDDEN, LATENT), e_bmu=jnp.zeros(LATENT),
        e_wlv=he(ks[2], ENC_HIDDEN, LATENT), e_blv=jnp.zeros(LATENT),
        lin_w=he(ks[3], LATENT, 3 * 3 * DEC_C1), lin_b=jnp.zeros(3 * 3 * DEC_C1),
        dc1_w=jax.random.normal(ks[4], (4, 4, DEC_C1, DEC_C2)) * 0.1,
        dc1_b=jnp.zeros(DEC_C2),
        dc2_w=jax.random.normal(ks[5], (4, 4, DEC_C2, 1)) * 0.1,
        dc2_b=jnp.zeros(1),
    )


def encode(params: VaeParams, x_flat):
    """x_flat (batch, 144) in [-1,1] -> (mu, logvar), each (batch, 2)."""
    h = jnp.maximum(x_flat @ params.e_w1 + params.e_b1, 0.0)
    return (h @ params.e_wmu + params.e_bmu,
            h @ params.e_wlv + params.e_blv)


def decoder_dict(params: VaeParams) -> dict:
    """Decoder params in the layout :func:`kernels.ref.vae_decoder` expects."""
    return dict(lin_w=params.lin_w, lin_b=params.lin_b,
                dc1_w=params.dc1_w, dc1_b=params.dc1_b,
                dc2_w=params.dc2_w, dc2_b=params.dc2_b)


def decode(params: VaeParams, z):
    """(batch, 2) latent -> (batch, 12, 12) image in [-1, 1]."""
    from .kernels import ref
    return ref.vae_decoder(z, decoder_dict(params))


def vae_loss(params: VaeParams, key, x_img, labels, gamma: float = KL_GAMMA):
    """Paper Eq. 10: MSE(X, X') + gamma * KL(N(mu, sigma^2) || N(mu_hat_c, 1))."""
    x_flat = x_img.reshape(x_img.shape[0], -1)
    mu, logvar = encode(params, x_flat)
    eps = jax.random.normal(key, mu.shape)
    z = mu + jnp.exp(0.5 * logvar) * eps
    recon = decode(params, z)
    mse = jnp.mean(jnp.sum((recon - x_img) ** 2, axis=(1, 2)))
    centers = jnp.asarray(CLASS_CENTERS)[labels]  # (batch, 2)
    kl = 0.5 * jnp.sum(jnp.exp(logvar) + (mu - centers) ** 2 - 1.0 - logvar,
                       axis=-1)
    return mse + gamma * jnp.mean(kl)


def train_vae(key, imgs: np.ndarray, labels: np.ndarray, steps: int = 3000,
              batch: int = 256, lr: float = 1e-3, gamma: float = KL_GAMMA):
    """Train the VAE; returns (params, final_loss)."""
    kinit, kloop = jax.random.split(key)
    params = init_vae(kinit)
    state = adam_init(params)
    imgs = jnp.asarray(imgs, jnp.float32)
    labels = jnp.asarray(labels, jnp.int32)

    @jax.jit
    def step_fn(params, state, key):
        kb, kl = jax.random.split(key)
        idx = jax.random.randint(kb, (batch,), 0, imgs.shape[0])
        loss, grads = jax.value_and_grad(vae_loss)(params, kl, imgs[idx],
                                                   labels[idx], gamma)
        params, state = adam_update(grads, state, params, lr=lr)
        return params, state, loss

    keys = jax.random.split(kloop, steps)
    loss = jnp.inf
    for i in range(steps):
        params, state, loss = step_fn(params, state, keys[i])
    return params, float(loss)


def encode_dataset(params: VaeParams, imgs: np.ndarray) -> np.ndarray:
    """Posterior means of the whole dataset — the latents the score net trains on."""
    mu, _ = encode(params, jnp.asarray(imgs).reshape(imgs.shape[0], -1))
    return np.asarray(mu, dtype=np.float32)
