"""Datasets for the two paper tasks.

1. ``circle``  — the unconditional 2-D circular distribution of Fig. 3.
2. ``letters`` — a procedural stand-in for the EMNIST letters H/K/U of
   Fig. 4.  EMNIST itself is not available offline; per DESIGN.md §3 we
   synthesize 12x12 glyphs with the same preprocessing geometry the paper
   describes (28x28 -> 14x14 downsample -> 12x12 center crop, range [-1,1]).
   The diffusion model operates in the VAE's 2-D latent space, so the
   experiment only needs three separable glyph classes — which these are.

Everything is numpy (build-time only) and fully seeded.
"""

from __future__ import annotations

import numpy as np

LETTERS = ("H", "K", "U")
IMG = 12  # final image side

# Latent-space class centers used by the VAE loss (paper Eq. 10's preset
# \hat{mu}_i).  Chosen 120 degrees apart so the three conditional
# distributions of Fig. 4d are well separated at radius 1.5.
CLASS_CENTERS = np.array(
    [
        [1.5, 0.0],                     # H
        [-0.75, 1.5 * np.sqrt(3) / 2],  # K
        [-0.75, -1.5 * np.sqrt(3) / 2], # U
    ],
    dtype=np.float32,
)


def sample_circle(n: int, rng: np.random.Generator, radius: float = 1.0,
                  radial_std: float = 0.05) -> np.ndarray:
    """Ground-truth circular distribution: radius ~ N(radius, radial_std), angle uniform."""
    theta = rng.uniform(0.0, 2.0 * np.pi, size=n)
    r = radius + radial_std * rng.standard_normal(n)
    return np.stack([r * np.cos(theta), r * np.sin(theta)], axis=1).astype(np.float32)


# --- glyph rasterization -----------------------------------------------------

def _base_glyph(letter: str, side: int = 24) -> np.ndarray:
    """Rasterize a canonical letter stroke pattern on a ``side x side`` canvas.

    Drawn oversized (24x24 ~ the spirit of EMNIST 28x28) and later
    downsampled + cropped to 12x12, mirroring the paper's preprocessing.
    """
    img = np.zeros((side, side), dtype=np.float32)
    lo, hi = side // 6, side - side // 6  # stroke extent
    w = max(2, side // 10)                # stroke width

    def vline(x0, y0, y1):
        img[y0:y1, x0:x0 + w] = 1.0

    def hline(y0, x0, x1):
        img[y0:y0 + w, x0:x1] = 1.0

    def dline(x0, y0, x1, y1):
        n = 2 * side
        xs = np.linspace(x0, x1, n)
        ys = np.linspace(y0, y1, n)
        for x, y in zip(xs, ys):
            xi, yi = int(round(x)), int(round(y))
            img[max(yi - w // 2, 0):yi + (w + 1) // 2,
                max(xi - w // 2, 0):xi + (w + 1) // 2] = 1.0

    if letter == "H":
        vline(lo, lo, hi)
        vline(hi - w, lo, hi)
        hline(side // 2 - w // 2, lo, hi)
    elif letter == "K":
        vline(lo, lo, hi)
        dline(lo + w, side // 2, hi - w // 2, lo + w // 2)
        dline(lo + w, side // 2, hi - w // 2, hi - w // 2)
    elif letter == "U":
        vline(lo, lo, hi - w)
        vline(hi - w, lo, hi - w)
        hline(hi - w, lo, hi)
    else:  # pragma: no cover - guarded by LETTERS
        raise ValueError(f"unknown letter {letter!r}")
    return img


def _random_affine(img: np.ndarray, rng: np.random.Generator,
                   max_rot: float = 0.18, max_shift: float = 1.5,
                   max_scale: float = 0.12) -> np.ndarray:
    """Apply a small random rotation/scale/shift by inverse nearest-neighbour mapping."""
    side = img.shape[0]
    theta = rng.uniform(-max_rot, max_rot)
    scale = 1.0 + rng.uniform(-max_scale, max_scale)
    tx, ty = rng.uniform(-max_shift, max_shift, size=2)
    c, s = np.cos(theta) / scale, np.sin(theta) / scale
    cy = cx = (side - 1) / 2.0
    ys, xs = np.mgrid[0:side, 0:side].astype(np.float32)
    xs0 = c * (xs - cx - tx) - s * (ys - cy - ty) + cx
    ys0 = s * (xs - cx - tx) + c * (ys - cy - ty) + cy
    xi = np.clip(np.round(xs0).astype(int), 0, side - 1)
    yi = np.clip(np.round(ys0).astype(int), 0, side - 1)
    valid = (xs0 >= 0) & (xs0 < side) & (ys0 >= 0) & (ys0 < side)
    return np.where(valid, img[yi, xi], 0.0).astype(np.float32)


def _blur3(img: np.ndarray) -> np.ndarray:
    """3x3 binomial blur (separable [1 2 1]/4), edge-padded."""
    k = np.array([1.0, 2.0, 1.0], dtype=np.float32) / 4.0
    p = np.pad(img, 1, mode="edge")
    h = k[0] * p[:, :-2] + k[1] * p[:, 1:-1] + k[2] * p[:, 2:]
    v = k[0] * h[:-2, :] + k[1] * h[1:-1, :] + k[2] * h[2:, :]
    return v.astype(np.float32)


def _downsample2(img: np.ndarray) -> np.ndarray:
    """2x2 average pooling — the paper's 28->14 downsample analogue (24->12... via 24->12)."""
    s = img.shape[0] // 2
    return img.reshape(s, 2, s, 2).mean(axis=(1, 3)).astype(np.float32)


def render_letter(letter: str, rng: np.random.Generator,
                  noise_std: float = 0.04) -> np.ndarray:
    """One 12x12 sample of ``letter`` in [-1, 1], EMNIST-like preprocessing.

    24x24 stroke canvas -> random affine -> blur -> 2x downsample (12x12)
    -> pixel noise -> rescale to [-1, 1].
    """
    img = _base_glyph(letter, side=2 * IMG)
    img = _random_affine(img, rng)
    img = _blur3(img)
    img = _downsample2(img)
    img = img + noise_std * rng.standard_normal(img.shape).astype(np.float32)
    img = np.clip(img, 0.0, 1.0)
    return (2.0 * img - 1.0).astype(np.float32)


def letters_dataset(n_per_class: int, seed: int = 0):
    """Full synthetic dataset: images ``(3n, 12, 12)`` in [-1,1] and labels ``(3n,)``."""
    rng = np.random.default_rng(seed)
    imgs, labels = [], []
    for ci, letter in enumerate(LETTERS):
        for _ in range(n_per_class):
            imgs.append(render_letter(letter, rng))
            labels.append(ci)
    order = rng.permutation(len(imgs))
    return (np.stack(imgs)[order].astype(np.float32),
            np.asarray(labels, dtype=np.int32)[order])
