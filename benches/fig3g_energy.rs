//! Bench: Fig. 3g — unconditional generation energy, analog vs digital.
//!
//! Uses the same matched-quality crossover search as fig3f and prints the
//! per-sample energy comparison (paper: 7.2 µJ analog, −80.8% vs digital),
//! plus the component breakdown of the analog power model.

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::energy::model::{
    AnalogCost, Comparison, DigitalCost, P_CELL_W, P_DAC_W, P_MULT_W, P_OPAMP_W,
};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::util::bench;
use memdiff::util::rng::Rng;
use memdiff::util::stats;

const N: usize = 1500;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    let mut rng = Rng::new(41);
    let mut truth_rng = Rng::new(42);
    let truth = sample_circle(40_000, &mut truth_rng);

    bench::section("Fig 3g: unconditional sampling energy at matched quality");

    let a = AnalogCost::unconditional_projected();
    bench::row(&["analog power breakdown:"]);
    bench::row(&[&format!("  crossbar cells ({})", a.n_cells),
                 &format!("{:.3} mW", 1e3 * a.n_cells as f64 * P_CELL_W)]);
    bench::row(&[&format!("  op-amps ({})", a.n_opamps),
                 &format!("{:.1} mW", 1e3 * a.n_opamps as f64 * P_OPAMP_W)]);
    bench::row(&[&format!("  multipliers ({})", a.n_mults),
                 &format!("{:.1} mW", 1e3 * a.n_mults as f64 * P_MULT_W)]);
    bench::row(&[&format!("  DACs ({})", a.n_dacs),
                 &format!("{:.1} mW", 1e3 * a.n_dacs as f64 * P_DAC_W)]);
    bench::row(&["  total", &format!("{:.1} mW", 1e3 * a.power_w())]);
    bench::row(&["analog energy/sample",
                 &format!("{:.2} uJ (paper: 7.2 uJ)", 1e6 * a.energy_j())]);

    // matched-quality crossover (same procedure as fig3f)
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
        .with_schedule(meta.sched).with_substeps(1500));
    let kl_analog = stats::kl_points(&solver.solve_batch(N, &[], &mut rng),
                                     &truth, 24, 2.0);
    let dig = DigitalScoreNet::new(w.clone());
    let sampler = DigitalSampler::new(&dig, SamplerMode::Sde).with_schedule(meta.sched);
    let mut matched = 512usize;
    for steps in [4usize, 8, 16, 32, 64, 96, 128, 192, 256, 384, 512] {
        let (pts, _) = sampler.sample_batch(N, &[], steps, &mut rng);
        if stats::kl_points(&pts, &truth, 24, 2.0) <= kl_analog * 1.05 {
            matched = steps;
            break;
        }
    }
    let d = DigitalCost::new(matched, 1);
    bench::row(&["digital energy/sample",
                 &format!("{:.2} uJ at {matched} steps", 1e6 * d.energy_j())]);
    let c = Comparison::of(&a, &d);
    bench::row(&["ENERGY REDUCTION",
                 &format!("{:.1}%  (paper Fig 3g: 80.8%)", c.energy_reduction_pct)]);
    Ok(())
}
