//! Bench: Fig. 3f — unconditional generation speed, analog vs digital at
//! matched quality.
//!
//! Sweeps the digital sampler's step count, measures generation KL per
//! point, finds the matched-quality crossover against the analog solver,
//! and prints the speed comparison row the paper reports (64.8×).

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::energy::model::{AnalogCost, Comparison, DigitalCost};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::util::bench;
use memdiff::util::rng::Rng;
use memdiff::util::stats;

const N: usize = 1500;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    let mut rng = Rng::new(31);
    let mut truth_rng = Rng::new(32);
    let truth = sample_circle(40_000, &mut truth_rng);

    bench::section("Fig 3f: unconditional sampling speed at matched quality");

    // analog reference quality
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
        .with_schedule(meta.sched).with_substeps(1500));
    let t0 = std::time::Instant::now();
    let gen = solver.solve_batch(N, &[], &mut rng);
    let analog_sim_wall = t0.elapsed();
    let kl_analog = stats::kl_points(&gen, &truth, 24, 2.0);
    bench::row(&["analog SDE (continuous)", &format!("KL={kl_analog:.4}"),
                 &format!("sim wall {analog_sim_wall:?} for {N}")]);

    // digital sweep
    let dig = DigitalScoreNet::new(w.clone());
    let sampler = DigitalSampler::new(&dig, SamplerMode::Sde).with_schedule(meta.sched);
    let mut matched = None;
    bench::row(&["steps", "KL(digital SDE)", "modeled latency/sample"]);
    for steps in [4usize, 8, 16, 32, 64, 96, 128, 192, 256, 384, 512] {
        let (pts, _) = sampler.sample_batch(N, &[], steps, &mut rng);
        let kl = stats::kl_points(&pts, &truth, 24, 2.0);
        let lat = DigitalCost::new(steps, 1).latency_s();
        bench::row(&[&format!("{steps:5}"), &format!("{kl:.4}"),
                     &format!("{:.1} us", 1e6 * lat)]);
        if matched.is_none() && kl <= kl_analog * 1.05 {
            matched = Some(steps);
        }
    }
    let steps = matched.unwrap_or(512);
    let c = Comparison::of(&AnalogCost::unconditional_projected(),
                           &DigitalCost::new(steps, 1));
    println!();
    bench::row(&["matched-quality steps", &steps.to_string()]);
    bench::row(&["analog latency/sample",
                 &format!("{:.1} us (paper: 20 us)", 1e6 * c.analog_latency_s)]);
    bench::row(&["digital latency/sample", &format!("{:.1} us", 1e6 * c.digital_latency_s)]);
    bench::row(&["SPEEDUP", &format!("{:.1}x  (paper Fig 3f: 64.8x)", c.speedup)]);
    Ok(())
}
