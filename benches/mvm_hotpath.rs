//! Bench: the analog-MVM hot path (the innermost loop of every solve).
//!
//! Compares the three crossbar noise fidelities in both lanes — scalar
//! `forward` (one vector) and batched `forward_batch` (B lanes per GEMM) —
//! plus a bank-grid sweep (monolithic oracle vs `BankedCrossbarLayer` at
//! 1×1 / 1×2 / 2×2 / 3×3 tile grids, capturing the tiling overhead), a
//! bank-parallel thread sweep (1/2/4/8-thread `exec::Pool` over a 3×3
//! grid, `par_*` keys), a SIMD-dispatch × shape sweep (scalar vs the best
//! detected instruction set on the batched GEMM, `simd_*` keys, plus the
//! conductance-quantized i8 lane, `quant_*` keys, and the autotuned tile
//! geometry), the fused analog score-net evaluation and one closed-loop
//! solver sub-step.  Per-MVM nanoseconds land in `BENCH_mvm.json` so the
//! perf trajectory is tracked across PRs.

use std::sync::Arc;

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::mapper::map_layer;
use memdiff::crossbar::{BankedCrossbarLayer, CrossbarLayer, NoiseModel};
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::exec::{Ctx, ParStrategy, Pool};
use memdiff::nn::{AnalogScoreNet, BatchScratch, ScoreNet, ScoreWeights};
use memdiff::util::bench;
use memdiff::util::qkernel::QuantBank;
use memdiff::util::rng::Rng;
use memdiff::util::simd::{self, KernelBackend};
use memdiff::util::tensor::{self, Mat};

/// Lanes per batched call — the coordinator's coalescing target.
const B: usize = 64;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(91);
    let mut json: Vec<(&str, f64)> = vec![("batch_size", B as f64)];

    bench::section("crossbar MVM 14x14, scalar vs batched (per-MVM cost)");
    let wmat = Mat::from_fn(14, 14, |_, _| 0.6 * rng.gaussian_f32());
    let (mut layer, _) = CrossbarLayer::program(&wmat, CellParams::default(), 0.0012, &mut rng);
    // pre-existing series stay pinned serial so their BENCH keys remain
    // comparable across PRs and machines; the par_* sweep below is the
    // parallel series with explicit thread counts
    layer.set_exec(Ctx::serial());
    let v = rng.gaussian_vec(14);
    let mut out = vec![0.0f32; 14];
    let vb: Vec<f32> = (0..B).flat_map(|_| v.iter().copied()).collect();
    let mut outb = vec![0.0f32; B * 14];
    for (label, key_s, key_b, nm) in [
        ("ideal", "mvm_ideal_scalar_ns", "mvm_ideal_batched_ns",
         NoiseModel::Ideal),
        ("read-fast", "mvm_read_fast_scalar_ns", "mvm_read_fast_batched_ns",
         NoiseModel::ReadFast),
        ("read-per-cell", "mvm_read_per_cell_scalar_ns",
         "mvm_read_per_cell_batched_ns", NoiseModel::ReadPerCell),
    ] {
        let r = bench::bench(&format!("mvm {label} scalar"), 150, || {
            layer.forward(&v, &mut out, nm, &mut rng);
            std::hint::black_box(&out);
        });
        bench::report(&r);
        json.push((key_s, r.mean_ns()));
        let rb = bench::bench(&format!("mvm {label} batched (B={B})"), 150, || {
            layer.forward_batch(&vb, &mut outb, B, nm, &mut rng);
            std::hint::black_box(&outb);
        });
        bench::report(&rb);
        let per_mvm = rb.mean_ns() / B as f64;
        println!("  => {per_mvm:.1} ns/MVM batched  ({:.2}x vs scalar)",
                 r.mean_ns() / per_mvm);
        json.push((key_b, per_mvm));
    }

    bench::section("bank-grid sweep: monolithic vs banked forward_batch (per-MVM cost)");
    // square layers spanning 1×1 → 3×3 tile grids (ragged on the 40 case)
    const GRIDS: &[(usize, &str, &str, &str)] = &[
        (32, "1x1", "bank_1x1_mono_ns", "bank_1x1_banked_ns"),
        (40, "2x2r", "bank_2x2r_mono_ns", "bank_2x2r_banked_ns"),
        (64, "2x2", "bank_2x2_mono_ns", "bank_2x2_banked_ns"),
        (96, "3x3", "bank_3x3_mono_ns", "bank_3x3_banked_ns"),
    ];
    for &(dim, label, key_mono, key_banked) in GRIDS {
        let wmat = Mat::from_fn(dim, dim, |_, _| 0.5 * rng.gaussian_f32());
        let m = map_layer(&wmat);
        let mut mono = CrossbarLayer::from_conductances(&m.g_target, m.gain,
                                                        CellParams::default());
        mono.set_exec(Ctx::serial()); // serial series: tiling overhead only
        let mut banked = BankedCrossbarLayer::from_conductances(
            &m.g_target, m.gain, CellParams::default(), 42);
        banked.set_exec(Ctx::serial());
        let vb: Vec<f32> = (0..B * dim).map(|_| rng.gaussian_f32()).collect();
        let mut outb = vec![0.0f32; B * dim];
        let rm = bench::bench(&format!("{label} ({dim}x{dim}) mono (B={B})"),
                              150, || {
            mono.forward_batch(&vb, &mut outb, B, NoiseModel::Ideal, &mut rng);
            std::hint::black_box(&outb);
        });
        bench::report(&rm);
        json.push((key_mono, rm.mean_ns() / B as f64));
        let rb = bench::bench(&format!("{label} ({dim}x{dim}) banked (B={B})"),
                              150, || {
            banked.forward_batch(&vb, &mut outb, B, NoiseModel::Ideal, &mut rng);
            std::hint::black_box(&outb);
        });
        bench::report(&rb);
        json.push((key_banked, rb.mean_ns() / B as f64));
        println!("  => {label}: banked/mono = {:.2}x ({} banks)",
                 rb.mean_ns() / rm.mean_ns(), banked.n_banks());
    }

    bench::section("bank-parallel thread sweep: banked 3x3 (96x96) forward_batch, B=64");
    // wall time of the whole batched call (not per-MVM) — the acceptance
    // series: par_3x3_t*_ns must fall from 1 → 4 threads.  Auto picks the
    // lane axis at B=64; the banks_* series pins the tile-column axis.
    {
        let dim = 96;
        let wmat = Mat::from_fn(dim, dim, |_, _| 0.5 * rng.gaussian_f32());
        let m = map_layer(&wmat);
        let vb: Vec<f32> = (0..B * dim).map(|_| rng.gaussian_f32()).collect();
        let mut outb = vec![0.0f32; B * dim];
        const SWEEP: &[(usize, &str, &str)] = &[
            (1, "par_3x3_t1_ns", "par_banks_3x3_t1_ns"),
            (2, "par_3x3_t2_ns", "par_banks_3x3_t2_ns"),
            (4, "par_3x3_t4_ns", "par_banks_3x3_t4_ns"),
            (8, "par_3x3_t8_ns", "par_banks_3x3_t8_ns"),
        ];
        let mut t1_auto = f64::NAN;
        let mut t4_auto = f64::NAN;
        for &(threads, key_auto, key_banks) in SWEEP {
            let pool = Arc::new(Pool::new(threads));
            for (strategy, key) in
                [(ParStrategy::Auto, key_auto), (ParStrategy::Banks, key_banks)]
            {
                let mut banked = BankedCrossbarLayer::from_conductances(
                    &m.g_target, m.gain, CellParams::default(), 42);
                banked.set_exec(Ctx::with_pool(strategy, pool.clone()));
                let r = bench::bench(
                    &format!("3x3 banked t={threads} {strategy} (B={B})"), 150,
                    || {
                        banked.forward_batch(&vb, &mut outb, B,
                                             NoiseModel::Ideal, &mut rng);
                        std::hint::black_box(&outb);
                    });
                bench::report(&r);
                json.push((key, r.mean_ns()));
                if strategy == ParStrategy::Auto {
                    if threads == 1 {
                        t1_auto = r.mean_ns();
                    } else if threads == 4 {
                        t4_auto = r.mean_ns();
                    }
                }
            }
        }
        json.push(("par_3x3_speedup_t4", t1_auto / t4_auto));
        println!("  => 1→4 thread speedup {:.2}x", t1_auto / t4_auto);
    }

    bench::section("SIMD dispatch x shape sweep: B x dim x dim GEMM + i8 quant lane");
    // scalar vs the best detected backend on the same batched GEMM the
    // crossbar hot path runs (order-preserving, so the speedup is free of
    // numeric drift), plus the conductance-quantized i8 lane on the same
    // shapes; the autotuned tile geometry those numbers were taken under
    // is recorded alongside them
    {
        let best = simd::active();
        let (row_block, tile_cols) = simd::tile_info();
        bench::row(&["dispatch",
                     &format!("active {best}, available {:?}, tile {row_block}x{tile_cols}",
                              simd::available().iter().map(|b| b.name())
                                  .collect::<Vec<_>>())]);
        json.push(("simd_row_block", row_block as f64));
        json.push(("simd_tile_cols", tile_cols as f64));
        const SHAPES: &[(usize, &str, &str, &str, &str)] = &[
            (32, "1x1", "simd_1x1_ns", "simd_speedup_1x1", "quant_1x1_ns"),
            (40, "2x2r", "simd_2x2r_ns", "simd_speedup_2x2r", "quant_2x2r_ns"),
            (64, "2x2", "simd_2x2_ns", "simd_speedup_2x2", "quant_2x2_ns"),
            (96, "3x3", "simd_3x3_ns", "simd_speedup_3x3", "quant_3x3_ns"),
        ];
        for &(dim, label, key_simd, key_speedup, key_quant) in SHAPES {
            let wmat = Mat::from_fn(dim, dim, |_, _| 0.5 * rng.gaussian_f32());
            let m = map_layer(&wmat);
            let a: Vec<f32> = (0..B * dim).map(|_| rng.gaussian_f32()).collect();
            let mut c = vec![0.0f32; B * dim];
            let rs = bench::bench(&format!("{label} ({dim}x{dim}) scalar GEMM (B={B})"),
                                  200, || {
                tensor::matmul_into_with(KernelBackend::Scalar, &a, wmat.as_slice(),
                                         &mut c, B, dim, dim);
                std::hint::black_box(&c);
            });
            bench::report(&rs);
            let rv = bench::bench(&format!("{label} ({dim}x{dim}) {best} GEMM (B={B})"),
                                  200, || {
                tensor::matmul_into_with(best, &a, wmat.as_slice(), &mut c,
                                         B, dim, dim);
                std::hint::black_box(&c);
            });
            bench::report(&rv);
            let speedup = rs.mean_ns() / rv.mean_ns();
            json.push((key_simd, rv.mean_ns() / B as f64));
            json.push((key_speedup, speedup));
            // i8 quant lane: full quantize -> accumulate -> dequantize cost
            let qb = QuantBank::from_conductances(&m.g_target);
            let mut qo = vec![0.0f32; B * dim];
            let rq = bench::bench(&format!("{label} ({dim}x{dim}) quant i8 (B={B})"),
                                  200, || {
                qb.forward_batch(&a, &mut qo, B, m.gain, best);
                std::hint::black_box(&qo);
            });
            bench::report(&rq);
            json.push((key_quant, rq.mean_ns() / B as f64));
            println!("  => {label}: {best}/scalar {speedup:.2}x, \
                      quant/{best} {:.2}x", rv.mean_ns() / rq.mean_ns());
        }
    }

    match Meta::load_default().and_then(|meta| {
        let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
        Ok((meta, w))
    }) {
        Ok((meta, w)) => {
            bench::section("fused score-net eval, scalar vs batched (per-eval cost)");
            for (label, key_s, key_b, nm) in [
                ("ideal", "eval_ideal_scalar_ns", "eval_ideal_batched_ns",
                 NoiseModel::Ideal),
                ("read-fast", "eval_read_fast_scalar_ns",
                 "eval_read_fast_batched_ns", NoiseModel::ReadFast),
                ("read-per-cell", "eval_read_per_cell_scalar_ns",
                 "eval_read_per_cell_batched_ns", NoiseModel::ReadPerCell),
            ] {
                let net = AnalogScoreNet::from_conductances(&w, CellParams::default(), nm)
                    .with_exec(Ctx::serial()); // serial series (see above)
                let mut o = [0.0f32; 2];
                let r = bench::bench(&format!("score eval {label} scalar"), 150, || {
                    net.eval(&[0.4, -0.2], 0.5, &[0.0, 0.0, 0.0], &mut o, &mut rng);
                    std::hint::black_box(&o);
                });
                bench::report(&r);
                json.push((key_s, r.mean_ns()));
                let xs: Vec<f32> = (0..B).flat_map(|_| [0.4f32, -0.2]).collect();
                let mut ob = vec![0.0f32; B * 2];
                let mut scratch = BatchScratch::new();
                let rb = bench::bench(
                    &format!("score eval {label} batched (B={B})"), 150, || {
                        net.eval_batch(&xs, 0.5, &[0.0, 0.0, 0.0], &mut ob,
                                       &mut scratch, &mut rng);
                        std::hint::black_box(&ob);
                    });
                bench::report(&rb);
                let per_eval = rb.mean_ns() / B as f64;
                println!("  => {per_eval:.1} ns/eval batched  ({:.2}x vs scalar)",
                         r.mean_ns() / per_eval);
                json.push((key_b, per_eval));
            }

            bench::section("closed-loop solver: one full solve (2000 substeps)");
            let net = AnalogScoreNet::from_conductances(
                &w, CellParams::default(), NoiseModel::ReadFast)
                .with_exec(Ctx::serial());
            let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
                .with_schedule(meta.sched));
            let mut trace = Vec::new();
            let r = bench::bench("solve 1 sample (SDE, 2000 substeps)", 400, || {
                let mut x = [rng.gaussian_f32(), rng.gaussian_f32()];
                solver.solve_into(&mut x, &[], &mut rng, 0, &mut trace);
                std::hint::black_box(x);
            });
            bench::report(&r);
            println!("  => per-substep cost {:?}", r.mean / 2000);
            json.push(("solve_scalar_ns", r.mean_ns()));
        }
        Err(e) => bench::row(&["score-net sections", &format!("skipped: {e}")]),
    }

    bench::write_json("BENCH_mvm.json", &json)?;
    Ok(())
}
