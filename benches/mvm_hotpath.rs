//! Bench: the analog-MVM hot path (the innermost loop of every solve).
//!
//! Compares the three crossbar noise fidelities, the fused analog score-net
//! evaluation, and one closed-loop solver sub-step — the quantities the
//! §Perf optimization pass tracks in EXPERIMENTS.md.

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::{CrossbarLayer, NoiseModel};
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, ScoreNet, ScoreWeights};
use memdiff::util::bench;
use memdiff::util::rng::Rng;
use memdiff::util::tensor::Mat;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(91);

    bench::section("crossbar MVM 14x14 (one hidden layer)");
    let wmat = Mat::from_fn(14, 14, |_, _| 0.6 * rng.gaussian_f32());
    let (layer, _) = CrossbarLayer::program(&wmat, CellParams::default(), 0.0012, &mut rng);
    let v = rng.gaussian_vec(14);
    let mut out = vec![0.0f32; 14];
    for (label, nm) in [("ideal", NoiseModel::Ideal),
                        ("read-fast (column stat)", NoiseModel::ReadFast),
                        ("read-per-cell (exact)", NoiseModel::ReadPerCell)] {
        let r = bench::bench(&format!("mvm {label}"), 150, || {
            layer.forward(&v, &mut out, nm, &mut rng);
            std::hint::black_box(&out);
        });
        bench::report(&r);
    }

    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;

    bench::section("fused score-net eval (3 layers + embedding)");
    for (label, nm) in [("ideal", NoiseModel::Ideal),
                        ("read-fast", NoiseModel::ReadFast),
                        ("read-per-cell", NoiseModel::ReadPerCell)] {
        let net = AnalogScoreNet::from_conductances(&w, CellParams::default(), nm);
        let mut o = [0.0f32; 2];
        let r = bench::bench(&format!("score eval {label}"), 150, || {
            net.eval(&[0.4, -0.2], 0.5, &[0.0, 0.0, 0.0], &mut o, &mut rng);
            std::hint::black_box(&o);
        });
        bench::report(&r);
    }

    bench::section("closed-loop solver: one full solve (2000 substeps)");
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
        .with_schedule(meta.sched));
    let mut trace = Vec::new();
    let r = bench::bench("solve 1 sample (SDE, 2000 substeps)", 400, || {
        let mut x = [rng.gaussian_f32(), rng.gaussian_f32()];
        solver.solve_into(&mut x, &[], &mut rng, 0, &mut trace);
        std::hint::black_box(x);
    });
    bench::report(&r);
    println!("  => per-substep cost {:?}", r.mean / 2000);
    Ok(())
}
