//! Bench: ablations of the design choices DESIGN.md §9 calls out.
//!
//!  A. Schedule strength — the paper's quoted β(T)=0.5 vs our β(T)=12:
//!     quantifies the prior-mismatch error the deviation fixes.
//!  B. Integrator order — Euler vs Heun vs RK4 on the probability-flow
//!     ODE at equal *network-evaluation* budget (the digital baseline's
//!     real cost unit).
//!  C. State clamp — solver substep budget sensitivity (continuity check).

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerKind, SamplerMode};
use memdiff::diffusion::VpSchedule;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::util::bench;
use memdiff::util::rng::Rng;
use memdiff::util::stats;

const N: usize = 1500;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    let mut rng = Rng::new(111);
    let mut truth_rng = Rng::new(112);
    let truth = sample_circle(40_000, &mut truth_rng);
    let dig = DigitalScoreNet::new(w.clone());

    bench::section("A. schedule strength (DESIGN.md §9.1)");
    bench::row(&["schedule", "alpha(T)", "KL (SDE-256, trained-net where applicable)"]);
    // our schedule, trained net
    let s = DigitalSampler::new(&dig, SamplerMode::Sde).with_schedule(meta.sched);
    let (pts, _) = s.sample_batch(N, &[], 256, &mut rng);
    bench::row(&["beta_max=12 (ours)",
                 &format!("{:.3}", meta.sched.alpha(meta.sched.t_end)),
                 &format!("{:.4}", stats::kl_points(&pts, &truth, 24, 2.0))]);
    // paper-quoted schedule with the same net: the prior mismatch dominates —
    // the net was trained for the strong schedule, so also report the
    // theoretical floor: sampling the quoted forward process itself.
    let quoted = VpSchedule::paper_quoted();
    let s = DigitalSampler::new(&dig, SamplerMode::Sde).with_schedule(quoted);
    let (pts, _) = s.sample_batch(N, &[], 256, &mut rng);
    bench::row(&["beta_max=0.5 (paper quoted), same net",
                 &format!("{:.3}", quoted.alpha(quoted.t_end)),
                 &format!("{:.4}", stats::kl_points(&pts, &truth, 24, 2.0))]);
    // theoretical prior mismatch of the quoted schedule: forward-diffuse the
    // data to T and compare against N(0,I) — the best any reverse process
    // started from N(0,I) could do is bounded by this gap
    let a = quoted.alpha(quoted.t_end) as f32;
    let sg = quoted.sigma(quoted.t_end) as f32;
    let fwd: Vec<f32> = sample_circle(N, &mut rng)
        .iter()
        .map(|&v| a * v) // scale data
        .collect::<Vec<f32>>()
        .chunks_exact(2)
        .flat_map(|p| [p[0] + sg * rng.gaussian_f32(), p[1] + sg * rng.gaussian_f32()])
        .collect();
    let prior: Vec<f32> = rng.gaussian_vec(2 * N);
    bench::row(&["quoted-schedule terminal vs N(0,I) (prior gap)",
                 "-",
                 &format!("{:.4}", stats::kl_points(&prior, &fwd, 24, 3.0))]);

    bench::section("B. integrator order at equal network-eval budget (ODE)");
    bench::row(&["scheme", "steps", "net evals", "KL"]);
    for (kind, steps, evals) in [(SamplerKind::Euler, 32usize, 32usize),
                                 (SamplerKind::Heun, 16, 32),
                                 (SamplerKind::Rk4, 8, 32),
                                 (SamplerKind::Euler, 128, 128),
                                 (SamplerKind::Heun, 64, 128),
                                 (SamplerKind::Rk4, 32, 128)] {
        let s = DigitalSampler::new(&dig, SamplerMode::Ode)
            .with_schedule(meta.sched)
            .with_kind(kind);
        let (pts, used) = s.sample_batch(N, &[], steps, &mut rng);
        assert_eq!(used, N * evals);
        bench::row(&[&format!("{kind:?}"), &steps.to_string(), &evals.to_string(),
                     &format!("{:.4}", stats::kl_points(&pts, &truth, 24, 2.0))]);
    }

    bench::section("C. analog solver substep-budget sensitivity");
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    bench::row(&["substeps", "KL (SDE)"]);
    for sub in [250usize, 500, 1000, 2000, 4000] {
        let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
            .with_schedule(meta.sched).with_substeps(sub));
        let gen = solver.solve_batch(N, &[], &mut rng);
        bench::row(&[&sub.to_string(),
                     &format!("{:.4}", stats::kl_points(&gen, &truth, 24, 2.0))]);
    }
    println!("\n(The plateau across substeps confirms the simulation grid is not");
    println!("a hidden discretization: the hardware's continuous loop is resolved.)");
    Ok(())
}
