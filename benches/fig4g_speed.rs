//! Bench: Fig. 4g — conditional (classifier-free guidance) generation
//! speed, analog vs digital at matched quality (paper: 156.5×).
//!
//! Quality metric (paper framing: "equivalent generative quality to the
//! software baseline"): worst-class KL of generated latents against a
//! converged 512-step digital reference at the same guidance strength.

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::NoiseModel;
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::energy::model::{AnalogCost, Comparison, DigitalCost};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::util::bench;
use memdiff::util::rng::Rng;
use memdiff::util::stats;

const N_PER_CLASS: usize = 500;
const GUIDANCE: f32 = 2.0;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_cond.json"))?;
    let mut rng = Rng::new(51);
    let dig = DigitalScoreNet::new(w.clone());

    bench::section("Fig 4g: conditional sampling speed at matched quality (CFG)");

    // converged software-baseline reference per class (512 steps, same λ)
    let mut references: Vec<Vec<f32>> = Vec::new();
    for c in 0..3 {
        let mut onehot = [0.0f32; 3];
        onehot[c] = 1.0;
        let sampler = DigitalSampler::new(&dig, SamplerMode::Sde)
            .with_schedule(meta.sched)
            .with_guidance(GUIDANCE);
        let (pts, _) = sampler.sample_batch(4 * N_PER_CLASS, &onehot, 512, &mut rng);
        references.push(pts);
    }

    // analog quality vs that reference
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let mut kl_analog: f64 = 0.0;
    for c in 0..3 {
        let mut onehot = [0.0f32; 3];
        onehot[c] = 1.0;
        let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
            .with_schedule(meta.sched).with_substeps(4000).with_guidance(GUIDANCE));
        let gen = solver.solve_batch(N_PER_CLASS, &onehot, &mut rng);
        kl_analog = kl_analog.max(stats::kl_points(&gen, &references[c], 20, 3.0));
    }
    bench::row(&["analog SDE+CFG", &format!("worst-class KL vs baseline = {kl_analog:.4}")]);

    // digital sweep (2 net evals per step for CFG)
    let mut matched = None;
    bench::row(&["steps", "worst-class KL", "modeled latency/sample"]);
    for steps in [4usize, 8, 16, 32, 64, 96, 128, 192, 256] {
        let mut worst: f64 = 0.0;
        for c in 0..3 {
            let mut onehot = [0.0f32; 3];
            onehot[c] = 1.0;
            let sampler = DigitalSampler::new(&dig, SamplerMode::Sde)
                .with_schedule(meta.sched)
                .with_guidance(GUIDANCE);
            let (pts, _) = sampler.sample_batch(N_PER_CLASS, &onehot, steps, &mut rng);
            worst = worst.max(stats::kl_points(&pts, &references[c], 20, 3.0));
        }
        let lat = DigitalCost::new(steps, 2).latency_s();
        bench::row(&[&format!("{steps:5}"), &format!("{worst:.4}"),
                     &format!("{:.1} us", 1e6 * lat)]);
        if matched.is_none() && worst <= kl_analog * 1.05 {
            matched = Some(steps);
        }
    }
    let steps = matched.unwrap_or(256);
    let c = Comparison::of(&AnalogCost::conditional_projected(),
                           &DigitalCost::new(steps, 2));
    println!();
    bench::row(&["matched-quality steps", &format!("{steps} (x2 CFG evals)")]);
    bench::row(&["SPEEDUP", &format!("{:.1}x  (paper Fig 4g: 156.5x)", c.speedup)]);
    Ok(())
}
