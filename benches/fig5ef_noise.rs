//! Bench: Fig. 5e/5f — generation quality vs analog noise magnitude,
//! ODE vs SDE (the noise-robustness claim).
//!
//! Rows: noise kind, magnitude, KL(ODE), KL(SDE).  Expected shape: flat
//! plateaus for small noise; SDE tolerates read noise better than ODE
//! (read fluctuation ≈ the Wiener term the SDE already integrates).

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, ScoreWeights};
use memdiff::util::bench;
use memdiff::util::rng::Rng;
use memdiff::util::stats;

const N: usize = 1000;

fn kl_for(net: &AnalogScoreNet, mode: SolverMode,
          sched: memdiff::diffusion::VpSchedule, truth: &[f32],
          rng: &mut Rng) -> f64 {
    let solver = AnalogSolver::new(net, SolverConfig::new(mode)
        .with_schedule(sched).with_substeps(1000));
    stats::kl_points(&solver.solve_batch(N, &[], rng), truth, 24, 2.0)
}

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    let mut rng = Rng::new(71);
    let mut truth_rng = Rng::new(72);
    let truth = sample_circle(40_000, &mut truth_rng);

    bench::section("Fig 5e/5f: KL vs analog noise magnitude (ODE vs SDE)");
    bench::row(&["kind ", "magnitude", "KL(ODE)", "KL(SDE)"]);

    for frac in [0.0f32, 0.005, 0.01, 0.02, 0.05, 0.10] {
        let params = CellParams { read_noise_frac: frac, ..CellParams::default() };
        let nm = if frac == 0.0 { NoiseModel::Ideal } else { NoiseModel::ReadFast };
        let net = AnalogScoreNet::from_conductances(&w, params, nm);
        let ode = kl_for(&net, SolverMode::Ode, meta.sched, &truth, &mut rng);
        let sde = kl_for(&net, SolverMode::Sde, meta.sched, &truth, &mut rng);
        bench::row(&["read ", &format!("{frac:9.3}"),
                     &format!("{ode:7.4}"), &format!("{sde:7.4}")]);
    }

    for tol in [0.0004f32, 0.0008, 0.0015, 0.003, 0.006] {
        let params = CellParams { read_noise_frac: 0.0, ..CellParams::default() };
        let mut prog_rng = Rng::new(7);
        let (net, _) = AnalogScoreNet::program_from_weights(
            &w, params, tol, NoiseModel::Ideal, &mut prog_rng);
        let ode = kl_for(&net, SolverMode::Ode, meta.sched, &truth, &mut rng);
        let sde = kl_for(&net, SolverMode::Sde, meta.sched, &truth, &mut rng);
        bench::row(&["write", &format!("{tol:9.4}"),
                     &format!("{ode:7.4}"), &format!("{sde:7.4}")]);
    }
    Ok(())
}
