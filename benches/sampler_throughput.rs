//! Bench: end-to-end sampler & coordinator throughput.
//!
//! Measures samples/second for (a) the analog simulator, (b) the rust
//! digital baseline, (c) the AOT PJRT path, and (d) the full batching
//! service under a mixed load — the serving-layer numbers a deployment
//! would track.  Every engine is measured in both lanes: scalar
//! (per-sample reference) and batched (the production matrix-matrix path
//! the coordinator routes through), plus (e) the TCP front-end over
//! loopback — sustained ticket latency/throughput and the reject rate of
//! the bounded lanes at deliberate saturation (`frontend_*` keys) — and
//! (f) the durable job queue — fsync'd enqueue-ack latency and drained
//! throughput (`jobs_*` keys) — and (g) the observability subsystem's
//! cost on the compute hot path, enabled vs disabled (`obs_*` keys,
//! budgeted at < 3% in `rust/src/obs/`) — and (h) the analog health
//! monitor's serving-path cost, ticking vs absent (`health_*` keys,
//! sharing the same < 3% budget) — and (i) the conductance-quantized i8
//! kernel lane on the same batched digital scenario (`quant_samples_per_s`,
//! the end-to-end serving throughput of a `kernel = quant` deployment).
//! The results land in
//! `BENCH_sampler_throughput.json` so the perf trajectory is tracked
//! across PRs.

use std::sync::Arc;

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::deploy::{self, BackendKind, DeployPlan};
use memdiff::coordinator::service::{AnalogEngine, Engine, RustDigitalEngine};
use memdiff::coordinator::{GenRequest, Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::crossbar::NoiseModel;
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::bench;
use memdiff::util::rng::Rng;

/// The batch size the coordinator coalesces to (matches the largest AOT
/// artifact batch) — the lane-comparison unit of this bench.
const B: usize = 64;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    let mut rng = Rng::new(101);

    bench::section("analog solver throughput, scalar vs batched (samples/s)");

    // scalar/batched lane series stay pinned serial so their BENCH keys
    // remain comparable across PRs and machines; pool usage is recorded
    // separately below (pool_* keys from the service section)
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast)
        .with_exec(memdiff::exec::Ctx::serial());
    let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
        .with_schedule(meta.sched).with_substeps(2000))
        .with_exec(memdiff::exec::Ctx::serial());
    let t0 = std::time::Instant::now();
    let n = 192;
    std::hint::black_box(solver.solve_batch(n, &[], &mut rng));
    let analog_scalar = n as f64 / t0.elapsed().as_secs_f64();
    bench::row(&["analog scalar (2000 substeps)",
                 &format!("{analog_scalar:.1} samples/s")]);

    let t0 = std::time::Instant::now();
    for _ in 0..(n / B) {
        std::hint::black_box(solver.solve_batched(B, &[], &mut rng));
    }
    let analog_batched = n as f64 / t0.elapsed().as_secs_f64();
    let label = format!("analog batched (B={B})");
    let val = format!("{analog_batched:.1} samples/s  ({:.2}x)",
                      analog_batched / analog_scalar);
    bench::row(&[label.as_str(), val.as_str()]);

    bench::section("rust digital throughput, scalar vs batched (samples/s)");

    let dig = DigitalScoreNet::new(w.clone())
        .with_exec(memdiff::exec::Ctx::serial());
    let sampler = DigitalSampler::new(&dig, SamplerMode::Sde)
        .with_schedule(meta.sched)
        .with_exec(memdiff::exec::Ctx::serial());
    let steps = 128;
    let reps_scalar = 16;
    let t0 = std::time::Instant::now();
    for _ in 0..reps_scalar {
        std::hint::black_box(sampler.sample_batch(B, &[], steps, &mut rng));
    }
    let digital_scalar =
        (reps_scalar * B) as f64 / t0.elapsed().as_secs_f64();
    let label = format!("rust digital scalar ({steps} steps, B={B})");
    let val = format!("{digital_scalar:.0} samples/s");
    bench::row(&[label.as_str(), val.as_str()]);

    let reps_batched = 64;
    let t0 = std::time::Instant::now();
    for _ in 0..reps_batched {
        std::hint::black_box(sampler.sample_batched(B, &[], steps, &mut rng));
    }
    let digital_batched =
        (reps_batched * B) as f64 / t0.elapsed().as_secs_f64();
    let digital_speedup = digital_batched / digital_scalar;
    let label = format!("rust digital batched ({steps} steps, B={B})");
    let val = format!("{digital_batched:.0} samples/s  ({digital_speedup:.2}x)");
    bench::row(&[label.as_str(), val.as_str()]);

    // conductance-quantized i8 lane on the same batched scenario — the
    // end-to-end throughput a `kernel = quant` deployment serves at
    let mut qdig = DigitalScoreNet::new(w.clone())
        .with_exec(memdiff::exec::Ctx::serial());
    qdig.set_kernel(memdiff::util::KernelMode::Quant);
    let qsampler = DigitalSampler::new(&qdig, SamplerMode::Sde)
        .with_schedule(meta.sched)
        .with_exec(memdiff::exec::Ctx::serial());
    let t0 = std::time::Instant::now();
    for _ in 0..reps_batched {
        std::hint::black_box(qsampler.sample_batched(B, &[], steps, &mut rng));
    }
    let quant_sps = (reps_batched * B) as f64 / t0.elapsed().as_secs_f64();
    let label = format!("rust digital quant i8 ({steps} steps, B={B})");
    let val = format!("{quant_sps:.0} samples/s  ({:.2}x vs f32 batched)",
                      quant_sps / digital_batched);
    bench::row(&[label.as_str(), val.as_str()]);

    // graceful: a failure here must not abort the bench (the JSON artifact
    // below still has to be written)
    let mut pjrt_sps = f64::NAN;
    let mut pjrt = || -> anyhow::Result<f64> {
        let store = ArtifactStore::open_default()?;
        store.warmup(64)?;
        let t0 = std::time::Instant::now();
        let n = 1024;
        for _ in 0..(n / 64) {
            std::hint::black_box(
                store.sample_digital(64, steps, true, None, &mut rng)?);
        }
        Ok(n as f64 / t0.elapsed().as_secs_f64())
    };
    match pjrt() {
        Ok(sps) => {
            pjrt_sps = sps;
            bench::row(&["PJRT artifacts (128 steps, b=64)",
                         &format!("{pjrt_sps:.0} samples/s")]);
        }
        Err(e) => bench::row(&["PJRT artifacts", &format!("skipped: {e}")]),
    }

    bench::section("coordinator throughput (4 workers, mixed load)");
    let engine = Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(w.clone()),
        sched: meta.sched,
    });
    let service = Arc::new(Service::start(engine, None, ServiceConfig {
        workers: 4,
        batcher: BatcherConfig {
            max_batch_samples: B,
            linger: std::time::Duration::from_millis(1),
            ..BatcherConfig::default()
        },
        seed: 3,
        intra_threads: 0,
    }));
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let total: usize = 96;
    for i in 0..total {
        rxs.push(service.submit(GenRequest {
            id: 0,
            task: TaskKind::Circle,
            n_samples: 8 + (i % 3) * 8,
            solver: SolverChoice::DigitalSde { steps: 100 },
            guidance: 0.0,
            decode: false,
            trace: memdiff::obs::TraceId::mint(),
        })?);
    }
    let mut samples = 0usize;
    for rx in rxs {
        samples += rx.recv()?.samples.len() / 2;
    }
    let service_sps = samples as f64 / t0.elapsed().as_secs_f64();
    bench::row(&["service (100-step SDE, batched lane)",
                 &format!("{service_sps:.0} samples/s over {total} requests")]);
    let snapshot = service.metrics.snapshot();
    bench::row(&["service metrics", &snapshot.report()]);
    // pool configuration/usage of this run, so the perf trajectory records
    // what parallelism the numbers were taken under
    let (pool_threads, pool_scopes, pool_tasks) = snapshot
        .pool
        .as_ref()
        .map(|p| (p.threads as f64, p.scopes_run as f64, p.tasks_run as f64))
        .unwrap_or((f64::NAN, f64::NAN, f64::NAN));
    drop(service);

    bench::section("deployment router, mixed-class traffic (analog + rust lanes)");
    // conditional weights so the router sees conditional classes too
    let wc = ScoreWeights::load(Meta::artifacts_dir().join("weights_cond.json"))?;
    let mut plan = DeployPlan::default(); // analog→analog, digital→rust
    plan.apply_overrides("analog_workers=2,rust_workers=2")?;
    let router = Arc::new(deploy::start_deployed(
        &plan,
        &mut |kind: BackendKind, _weights: Option<&str>| {
            Ok(match kind {
                // short solve window (250 substeps): this scenario
                // measures routing + lane isolation, not the full solve
                BackendKind::Analog => Arc::new(AnalogEngine::new(
                    AnalogScoreNet::from_conductances(
                        &wc, CellParams::default(), NoiseModel::ReadFast),
                    meta.sched,
                    250,
                )) as Arc<dyn Engine>,
                BackendKind::Rust => Arc::new(RustDigitalEngine {
                    net: DigitalScoreNet::new(wc.clone()),
                    sched: meta.sched,
                }) as Arc<dyn Engine>,
                BackendKind::Hlo => anyhow::bail!("not deployed in this bench"),
            })
        },
        None,
        ServiceConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch_samples: B,
                linger: std::time::Duration::from_millis(1),
                ..BatcherConfig::default()
            },
            seed: 17,
            intra_threads: 0,
        },
    )?);
    let t0 = std::time::Instant::now();
    let total_mixed = 60usize;
    let mut rxs = Vec::new();
    for i in 0..total_mixed {
        // interleaved AnalogOde + DigitalOde + conditional DigitalSde
        let (task, solver, n) = match i % 3 {
            0 => (TaskKind::Circle, SolverChoice::AnalogOde, 4),
            1 => (TaskKind::Circle, SolverChoice::DigitalOde { steps: 100 }, 16),
            _ => (TaskKind::Letter((i / 3) % 3),
                  SolverChoice::DigitalSde { steps: 100 }, 16),
        };
        rxs.push(router.submit(GenRequest {
            id: 0,
            task,
            n_samples: n,
            solver,
            guidance: 2.0,
            decode: false,
            trace: memdiff::obs::TraceId::mint(),
        })?);
    }
    let mut mixed_samples = 0usize;
    for rx in rxs {
        mixed_samples += rx.recv()?.samples.len() / 2;
    }
    let router_wall = t0.elapsed().as_secs_f64();
    let router_sps = mixed_samples as f64 / router_wall;
    let rsnap = router.metrics.snapshot();
    bench::row(&["router (mixed classes, 2 backends)",
                 &format!("{router_sps:.0} samples/s over {total_mixed} requests")]);
    bench::row(&["router metrics", &rsnap.report()]);
    // per-backend throughput/latency for the perf trajectory
    let backend = |name: &str| rsnap.backends.iter().find(|b| b.name == name);
    let (router_analog_sps, router_analog_lat) = backend("analog")
        .map(|b| (b.samples as f64 / router_wall, b.mean_latency_s))
        .unwrap_or((f64::NAN, f64::NAN));
    let (router_rust_sps, router_rust_lat) = backend("rust")
        .map(|b| (b.samples as f64 / router_wall, b.mean_latency_s))
        .unwrap_or((f64::NAN, f64::NAN));

    bench::section("TCP front-end over loopback (tickets, bounded lanes)");
    // a digital-only deployment behind the line-JSON front-end: small
    // bounded lanes so the saturation burst measurably sheds
    let frontend_queue_depth = 2 * B;
    let fe_engine = Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(w.clone()),
        sched: meta.sched,
    });
    let mut fe_reg = memdiff::coordinator::EngineRegistry::new();
    fe_reg.add_backend("rust", fe_engine, 2)?;
    fe_reg.route_family(memdiff::coordinator::SolverFamily::Digital, "rust")?;
    let fe_service = Service::start_routed(fe_reg, None, ServiceConfig {
        workers: 2,
        batcher: BatcherConfig {
            max_batch_samples: B,
            linger: std::time::Duration::from_millis(1),
            queue_depth: frontend_queue_depth,
        },
        seed: 23,
        intra_threads: 0,
    });
    let front = memdiff::serve::FrontEnd::bind(
        fe_service, "127.0.0.1:0", memdiff::serve::FrontEndConfig::default())?;
    let addr = front.local_addr();
    let fe_metrics = front.metrics();

    use memdiff::serve::protocol::{self, Status};
    use std::io::{BufReader, Write as _};
    let stream = std::net::TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut fe_writer = stream.try_clone()?;
    let mut fe_reader = BufReader::new(stream);
    use memdiff::serve::protocol::read_reply;

    // sustained phase: windowed pacing (4 in flight) — per-ticket wire
    // latency and throughput under a load the bounded lanes can carry
    let sustained_total = 192usize;
    let window = 4usize;
    let fe_n = 8usize;
    let mut sent = 0usize;
    let mut done = 0usize;
    let mut t_sent: Vec<std::time::Instant> = Vec::with_capacity(sustained_total);
    let mut lats: Vec<f64> = Vec::with_capacity(sustained_total);
    let t0 = std::time::Instant::now();
    while done < sustained_total {
        while sent < sustained_total && sent - done < window {
            let line = protocol::request_line(
                sent as u64, TaskKind::Circle, fe_n,
                SolverChoice::DigitalSde { steps: 100 }, 0.0, false);
            fe_writer.write_all(line.as_bytes())?;
            fe_writer.write_all(b"\n")?;
            t_sent.push(std::time::Instant::now());
            sent += 1;
        }
        let reply = read_reply(&mut fe_reader)?;
        anyhow::ensure!(reply.status == Status::Ok, "sustained reject");
        lats.push(t_sent[reply.id as usize].elapsed().as_secs_f64());
        done += 1;
    }
    let fe_wall = t0.elapsed().as_secs_f64();
    let fe_sps = (sustained_total * fe_n) as f64 / fe_wall;
    let fe_p50 = memdiff::util::stats::percentile(&lats, 50.0);
    let fe_p99 = memdiff::util::stats::percentile(&lats, 99.0);
    bench::row(&["front-end sustained (windowed, B=8/req)",
                 &format!("{fe_sps:.0} samples/s  p50 {:.1} ms  p99 {:.1} ms",
                          1e3 * fe_p50, 1e3 * fe_p99)]);

    // saturation phase: unpaced burst of 4x the lane bound — the reject
    // rate is the shed fraction the 429-path absorbs at the edge
    let burst_total = (8 * frontend_queue_depth / fe_n).max(32);
    for i in 0..burst_total {
        let line = protocol::request_line(
            (10_000 + i) as u64, TaskKind::Circle, fe_n,
            SolverChoice::DigitalSde { steps: 100 }, 0.0, false);
        fe_writer.write_all(line.as_bytes())?;
        fe_writer.write_all(b"\n")?;
    }
    let mut burst_ok = 0usize;
    let mut burst_shed = 0usize;
    for _ in 0..burst_total {
        match read_reply(&mut fe_reader)?.status {
            Status::Ok => burst_ok += 1,
            Status::Overloaded => burst_shed += 1,
            other => anyhow::bail!("unexpected burst status {other:?}"),
        }
    }
    let fe_reject_rate = burst_shed as f64 / burst_total as f64;
    bench::row(&["front-end saturation burst",
                 &format!("{burst_ok} ok / {burst_shed} shed \
                           (reject rate {:.0}%)", 100.0 * fe_reject_rate)]);
    drop(fe_writer);
    drop(fe_reader);
    front.shutdown();
    let fe_snap = fe_metrics.snapshot();
    bench::row(&["front-end metrics", &fe_snap.report()]);

    bench::section("durable job queue (fsync'd enqueue ack, end-to-end)");
    // the submit-now/fetch-later path: every enqueue pays one fsync before
    // it is acknowledged, so both the ack latency and the drained
    // throughput land in the perf trajectory
    let jobs_dir = std::env::temp_dir()
        .join(format!("memdiff_bench_jobs_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&jobs_dir);
    let jq_service = Arc::new(Service::start(
        Arc::new(RustDigitalEngine {
            net: DigitalScoreNet::new(w.clone()),
            sched: meta.sched,
        }),
        None,
        ServiceConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch_samples: B,
                linger: std::time::Duration::from_millis(1),
                ..BatcherConfig::default()
            },
            seed: 29,
            intra_threads: 0,
        },
    ));
    let jq_store = Arc::new(memdiff::jobs::JobStore::open(&jobs_dir)?);
    let jq_runner = memdiff::jobs::JobRunner::start(
        Arc::clone(&jq_service),
        Arc::clone(&jq_store),
        memdiff::jobs::RunnerConfig::default(),
    );
    let jobs_total = 48usize;
    let jobs_n = 8usize;
    let mut enq_lats: Vec<f64> = Vec::with_capacity(jobs_total);
    let t0 = std::time::Instant::now();
    let job_ids: Vec<u64> = (0..jobs_total)
        .map(|_| {
            let t = std::time::Instant::now();
            let id = jq_runner
                .enqueue(
                    &GenRequest {
                        id: 0,
                        task: TaskKind::Circle,
                        n_samples: jobs_n,
                        solver: SolverChoice::DigitalSde { steps: 100 },
                        guidance: 0.0,
                        decode: false,
                        trace: memdiff::obs::TraceId::NONE,
                    },
                    0,
                    None,
                    None,
                )
                .expect("durable enqueue");
            enq_lats.push(t.elapsed().as_secs_f64());
            id
        })
        .collect();
    let mut jobs_samples = 0usize;
    for id in job_ids {
        let j = jq_runner
            .wait_result(id, std::time::Duration::from_secs(120))
            .expect("job resolves");
        anyhow::ensure!(j.state == memdiff::jobs::JobState::Done,
                        "bench job {id} ended {:?} ({:?})", j.state, j.error);
        jobs_samples += j.result.map_or(0, |r| r.samples.len() / 2);
    }
    let jobs_wall = t0.elapsed().as_secs_f64();
    let jobs_sps = jobs_samples as f64 / jobs_wall;
    let jobs_enq_p50 = memdiff::util::stats::percentile(&enq_lats, 50.0);
    bench::row(&["job queue (100-step SDE, B=8/job)",
                 &format!("{jobs_sps:.0} samples/s over {jobs_total} jobs  \
                           enqueue-ack p50 {:.2} ms", 1e3 * jobs_enq_p50)]);
    bench::row(&["job gauges", &jq_store.gauges().summary()]);
    jq_runner.drain();
    drop(jq_runner);
    drop(jq_service);
    drop(jq_store);
    let _ = std::fs::remove_dir_all(&jobs_dir);

    bench::section("observability overhead (phase timers + spans, on vs off)");
    // same batched digital lane as above: enabled is the default serving
    // configuration, disabled strips every probe to one atomic load — the
    // delta is the price of the [obs] subsystem on the compute hot path
    let obs_reps = 24usize;
    memdiff::obs::set_enabled(true);
    let t0 = std::time::Instant::now();
    for _ in 0..obs_reps {
        std::hint::black_box(sampler.sample_batched(B, &[], steps, &mut rng));
    }
    let obs_on_sps = (obs_reps * B) as f64 / t0.elapsed().as_secs_f64();
    memdiff::obs::set_enabled(false);
    let t0 = std::time::Instant::now();
    for _ in 0..obs_reps {
        std::hint::black_box(sampler.sample_batched(B, &[], steps, &mut rng));
    }
    let obs_off_sps = (obs_reps * B) as f64 / t0.elapsed().as_secs_f64();
    memdiff::obs::set_enabled(true);
    let obs_overhead_pct = 100.0 * (obs_off_sps - obs_on_sps) / obs_off_sps;
    bench::row(&["obs overhead (batched digital lane)",
                 &format!("on {obs_on_sps:.0} / off {obs_off_sps:.0} \
                           samples/s  ({obs_overhead_pct:+.2}%)")]);

    bench::section("health monitor overhead (drift ticks vs serving, on vs off)");
    // the router deployment again, now with the monitor's retention clock
    // ticking aggressively (20 ms cadence, aging under the programming
    // gate every tick) — the delta is the mode-gate + drift-refresh cost
    // the serving path pays for live health tracking
    let health_load = |reps: usize| -> anyhow::Result<f64> {
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::new();
        for i in 0..reps {
            let (task, solver, n) = match i % 3 {
                0 => (TaskKind::Circle, SolverChoice::AnalogOde, 4),
                1 => (TaskKind::Circle,
                      SolverChoice::DigitalOde { steps: 100 }, 16),
                _ => (TaskKind::Letter((i / 3) % 3),
                      SolverChoice::DigitalSde { steps: 100 }, 16),
            };
            rxs.push(router.submit(GenRequest {
                id: 0,
                task,
                n_samples: n,
                solver,
                guidance: 2.0,
                decode: false,
                trace: memdiff::obs::TraceId::mint(),
            })?);
        }
        let mut s = 0usize;
        for rx in rxs {
            s += rx.recv()?.samples.len() / 2;
        }
        Ok(s as f64 / t0.elapsed().as_secs_f64())
    };
    let health_off_sps = health_load(total_mixed)?;
    let mon = memdiff::obs::HealthMonitor::new(
        memdiff::obs::HealthConfig {
            tick_ms: 20,
            // small but nonzero: every tick takes the programming gate
            // and re-reads the drift report, without crossing the alert
            // threshold over the run
            retention_dt_s: 1e3,
            probe_interval_ms: 0,
            ..memdiff::obs::HealthConfig::default()
        },
        Arc::clone(router.registry()),
        Arc::clone(&router.mode_gate),
    );
    mon.start();
    let health_on_sps = health_load(total_mixed)?;
    mon.stop();
    let health_overhead_pct =
        100.0 * (health_off_sps - health_on_sps) / health_off_sps;
    bench::row(&["health overhead (routed mixed load)",
                 &format!("off {health_off_sps:.0} / on {health_on_sps:.0} \
                           samples/s  ({health_overhead_pct:+.2}%)")]);

    bench::section("slo engine overhead (burn-rate ticks vs serving, on vs off)");
    // two monitors with identical health knobs on an aggressive 20 ms
    // tick; the only difference is the SLO engine evaluating its
    // burn-rate windows over the per-class latency histograms each tick
    // (the hot-path recording itself rides the [obs] switch, measured
    // above) — the delta is what the ISSUE's <3% budget bounds
    let slo_mon = |enabled: bool| {
        memdiff::obs::HealthMonitor::new_full(
            memdiff::obs::HealthConfig {
                tick_ms: 20,
                probe_interval_ms: 0,
                ..memdiff::obs::HealthConfig::default()
            },
            memdiff::obs::SloConfig { enabled, ..Default::default() },
            Arc::clone(router.registry()),
            Arc::clone(&router.mode_gate),
            None,
        )
    };
    let m_off = slo_mon(false);
    m_off.start();
    let slo_off_sps = health_load(total_mixed)?;
    m_off.stop();
    let m_on = slo_mon(true);
    m_on.start();
    let slo_on_sps = health_load(total_mixed)?;
    m_on.stop();
    let slo_overhead_pct = 100.0 * (slo_off_sps - slo_on_sps) / slo_off_sps;
    bench::row(&["slo overhead (routed mixed load)",
                 &format!("off {slo_off_sps:.0} / on {slo_on_sps:.0} \
                           samples/s  ({slo_overhead_pct:+.2}%)")]);

    bench::write_json("BENCH_sampler_throughput.json", &[
        ("batch_size", B as f64),
        ("digital_scalar_samples_per_s", digital_scalar),
        ("digital_batched_samples_per_s", digital_batched),
        ("digital_batched_speedup", digital_speedup),
        ("quant_samples_per_s", quant_sps),
        ("quant_vs_f32_speedup", quant_sps / digital_batched),
        ("analog_scalar_samples_per_s", analog_scalar),
        ("analog_batched_samples_per_s", analog_batched),
        ("analog_batched_speedup", analog_batched / analog_scalar),
        ("pjrt_samples_per_s", pjrt_sps),
        ("service_samples_per_s", service_sps),
        ("pool_threads", pool_threads),
        ("pool_scopes_run", pool_scopes),
        ("pool_tasks_run", pool_tasks),
        ("router_total_samples_per_s", router_sps),
        ("router_analog_samples_per_s", router_analog_sps),
        ("router_rust_samples_per_s", router_rust_sps),
        ("router_analog_mean_latency_s", router_analog_lat),
        ("router_rust_mean_latency_s", router_rust_lat),
        ("router_degraded", rsnap.degraded.len() as f64),
        ("frontend_queue_depth", frontend_queue_depth as f64),
        ("frontend_samples_per_s", fe_sps),
        ("frontend_p50_ticket_latency_s", fe_p50),
        ("frontend_p99_ticket_latency_s", fe_p99),
        ("frontend_saturation_reject_rate", fe_reject_rate),
        ("frontend_rejected", fe_snap.rejected as f64),
        ("jobs_samples_per_s", jobs_sps),
        ("jobs_enqueue_fsync_p50_s", jobs_enq_p50),
        ("obs_on_samples_per_s", obs_on_sps),
        ("obs_off_samples_per_s", obs_off_sps),
        ("obs_overhead_pct", obs_overhead_pct),
        ("health_on_samples_per_s", health_on_sps),
        ("health_off_samples_per_s", health_off_sps),
        ("health_overhead_pct", health_overhead_pct),
        ("slo_on_samples_per_s", slo_on_sps),
        ("slo_off_samples_per_s", slo_off_sps),
        ("slo_overhead_pct", slo_overhead_pct),
    ])?;
    Ok(())
}
