//! Bench: end-to-end sampler & coordinator throughput.
//!
//! Measures samples/second for (a) the analog simulator, (b) the rust
//! digital baseline, (c) the AOT PJRT path, and (d) the full batching
//! service under a mixed load — the serving-layer numbers a deployment
//! would track.

use std::sync::Arc;

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::service::RustDigitalEngine;
use memdiff::coordinator::{GenRequest, Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::crossbar::NoiseModel;
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::bench;
use memdiff::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    let mut rng = Rng::new(101);

    bench::section("single-thread sampler throughput (samples/s)");

    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
        .with_schedule(meta.sched).with_substeps(2000));
    let t0 = std::time::Instant::now();
    let n = 200;
    std::hint::black_box(solver.solve_batch(n, &[], &mut rng));
    let dt = t0.elapsed().as_secs_f64();
    bench::row(&["analog sim (2000 substeps)",
                 &format!("{:.1} samples/s", n as f64 / dt)]);

    let dig = DigitalScoreNet::new(w.clone());
    let sampler = DigitalSampler::new(&dig, SamplerMode::Sde).with_schedule(meta.sched);
    let t0 = std::time::Instant::now();
    let n = 2000;
    std::hint::black_box(sampler.sample_batch(n, &[], 128, &mut rng));
    let dt = t0.elapsed().as_secs_f64();
    bench::row(&["rust digital (128 steps)",
                 &format!("{:.0} samples/s", n as f64 / dt)]);

    let store = ArtifactStore::open_default()?;
    store.warmup(64)?;
    let t0 = std::time::Instant::now();
    let n = 1024;
    for _ in 0..(n / 64) {
        std::hint::black_box(store.sample_digital(64, 128, true, None, &mut rng)?);
    }
    let dt = t0.elapsed().as_secs_f64();
    bench::row(&["PJRT artifacts (128 steps, b=64)",
                 &format!("{:.0} samples/s", n as f64 / dt)]);

    bench::section("coordinator throughput (4 workers, mixed load)");
    let engine = Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(w.clone()),
        sched: meta.sched,
    });
    let service = Arc::new(Service::start(engine, None, ServiceConfig {
        workers: 4,
        batcher: BatcherConfig {
            max_batch_samples: 64,
            linger: std::time::Duration::from_millis(1),
        },
        seed: 3,
    }));
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let total: usize = 96;
    for i in 0..total {
        rxs.push(service.submit(GenRequest {
            id: 0,
            task: TaskKind::Circle,
            n_samples: 8 + (i % 3) * 8,
            solver: SolverChoice::DigitalSde { steps: 100 },
            guidance: 0.0,
            decode: false,
        })?);
    }
    let mut samples = 0usize;
    for rx in rxs {
        samples += rx.recv()??.samples.len() / 2;
    }
    let dt = t0.elapsed().as_secs_f64();
    bench::row(&["service (100-step SDE)",
                 &format!("{:.0} samples/s over {total} requests", samples as f64 / dt)]);
    bench::row(&["service metrics", &service.metrics.snapshot().report()]);
    Ok(())
}
