//! Bench: Fig. 2 — device-level figures of merit as measurable rows, plus
//! timing of the device simulator's primitive operations.

use memdiff::device::{Cell, Macro};
use memdiff::util::bench;
use memdiff::util::rng::Rng;
use memdiff::util::stats;
use memdiff::util::tensor::Mat;

fn main() {
    let mut rng = Rng::new(81);

    bench::section("Fig 2c: 200-cycle IV repeatability");
    let up: Vec<f32> = (0..60).map(|i| 1.5 * i as f32 / 59.0).collect();
    let dn: Vec<f32> = (0..60).map(|i| -1.5 * i as f32 / 59.0).collect();
    let mut cell = Cell::with_default(0.02);
    let mut finals = Vec::new();
    for _ in 0..200 {
        let _ = cell.iv_sweep(&up, &mut rng);
        finals.push(cell.conductance());
        let _ = cell.iv_sweep(&dn, &mut rng);
    }
    bench::row(&["post-SET conductance",
                 &format!("{:.4} ± {:.4} mS (CV {:.1}%)",
                          stats::mean(&finals), stats::std(&finals),
                          100.0 * stats::std(&finals) / stats::mean(&finals))]);

    bench::section("Fig 2d: programmed-state discernibility");
    let mut overlaps = 0;
    let mut prev_hi = f32::MIN;
    for k in 0..64 {
        let mut c = Cell::with_default(0.05);
        c.program_verify(Cell::level_conductance(k), 0.0005, 2000, &mut rng);
        let reads: Vec<f32> = (0..200).map(|_| c.read(&mut rng)).collect();
        let (m, s) = (stats::mean(&reads) as f32, stats::std(&reads) as f32);
        if m - 2.0 * s < prev_hi {
            overlaps += 1;
        }
        prev_hi = m + 2.0 * s;
    }
    bench::row(&["levels with 2-sigma overlap", &format!("{overlaps}/64")]);

    bench::section("Fig 2f/2g: array programming + error stats");
    let mut array = Macro::new(32, 32);
    let pattern = Macro::moon_star_pattern(32);
    let st = array.program(&pattern, 0.0015, 500, &mut rng);
    bench::row(&["mean pulses/cell", &format!("{:.1}", st.mean_pulses())]);
    bench::row(&["program failures", &st.failures.to_string()]);
    let read = array.read_all(&mut rng);
    let errs: Vec<f32> = read.as_slice().iter().zip(pattern.as_slice())
        .map(|(r, t)| 100.0 * (r - t) / t).collect();
    bench::row(&["relative error", &format!("{:+.3}% ± {:.3}%",
                                            stats::mean(&errs), stats::std(&errs))]);

    bench::section("device-simulator primitive timings");
    let c = Cell::with_default(0.06);
    let r1 = bench::bench("cell.read", 200, || {
        std::hint::black_box(c.read(&mut rng));
    });
    bench::report(&r1);
    let mut c2 = Cell::with_default(0.05);
    let r2 = bench::bench("cell.program_verify (tol 1.5e-3)", 300, || {
        c2 = Cell::with_default(0.05);
        std::hint::black_box(c2.program_verify(0.08, 0.0015, 500, &mut rng));
    });
    bench::report(&r2);
    let v = vec![0.3f32; 32];
    let mut out = vec![0.0f32; 32];
    let r3 = bench::bench("macro.mvm 32x32 (per-cell noise)", 300, || {
        array.mvm(&v, &mut out, &mut rng);
        std::hint::black_box(&out);
    });
    bench::report(&r3);
    let r4 = bench::bench("macro.program 32x32", 500, || {
        let mut m = Macro::new(32, 32);
        std::hint::black_box(m.program(&Mat::full(32, 32, 0.06), 0.0015, 500, &mut rng));
    });
    bench::report(&r4);
}
