//! Bench: Fig. 4h — conditional generation energy, analog vs digital
//! (paper: −75.6%).  Same matched-quality crossover as fig4g (KL vs the
//! converged 512-step software baseline per class).

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::NoiseModel;
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::energy::model::{AnalogCost, Comparison, DigitalCost};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::util::bench;
use memdiff::util::rng::Rng;
use memdiff::util::stats;

const N_PER_CLASS: usize = 500;
const GUIDANCE: f32 = 2.0;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_cond.json"))?;
    let mut rng = Rng::new(61);
    let dig = DigitalScoreNet::new(w.clone());

    bench::section("Fig 4h: conditional sampling energy at matched quality");
    let a = AnalogCost::conditional_projected();
    bench::row(&["analog power (CFG: dual score path)",
                 &format!("{:.1} mW", 1e3 * a.power_w())]);
    bench::row(&["analog energy/sample", &format!("{:.2} uJ", 1e6 * a.energy_j())]);

    // converged baseline references
    let mut references: Vec<Vec<f32>> = Vec::new();
    for c in 0..3 {
        let mut onehot = [0.0f32; 3];
        onehot[c] = 1.0;
        let sampler = DigitalSampler::new(&dig, SamplerMode::Sde)
            .with_schedule(meta.sched)
            .with_guidance(GUIDANCE);
        let (pts, _) = sampler.sample_batch(4 * N_PER_CLASS, &onehot, 512, &mut rng);
        references.push(pts);
    }

    // analog quality
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    let mut kl_analog: f64 = 0.0;
    for c in 0..3 {
        let mut onehot = [0.0f32; 3];
        onehot[c] = 1.0;
        let solver = AnalogSolver::new(&net, SolverConfig::new(SolverMode::Sde)
            .with_schedule(meta.sched).with_substeps(4000).with_guidance(GUIDANCE));
        let gen = solver.solve_batch(N_PER_CLASS, &onehot, &mut rng);
        kl_analog = kl_analog.max(stats::kl_points(&gen, &references[c], 20, 3.0));
    }

    // crossover
    let mut matched = 256usize;
    'outer: for steps in [4usize, 8, 16, 32, 64, 96, 128, 192, 256] {
        let mut worst: f64 = 0.0;
        for c in 0..3 {
            let mut onehot = [0.0f32; 3];
            onehot[c] = 1.0;
            let sampler = DigitalSampler::new(&dig, SamplerMode::Sde)
                .with_schedule(meta.sched)
                .with_guidance(GUIDANCE);
            let (pts, _) = sampler.sample_batch(N_PER_CLASS, &onehot, steps, &mut rng);
            worst = worst.max(stats::kl_points(&pts, &references[c], 20, 3.0));
        }
        if worst <= kl_analog * 1.05 {
            matched = steps;
            break 'outer;
        }
    }
    let d = DigitalCost::new(matched, 2);
    bench::row(&["digital energy/sample",
                 &format!("{:.2} uJ at {matched} steps x2 evals", 1e6 * d.energy_j())]);
    let c = Comparison::of(&a, &d);
    bench::row(&["ENERGY REDUCTION",
                 &format!("{:.1}%  (paper Fig 4h: 75.6%)", c.energy_reduction_pct)]);
    Ok(())
}
